"""TuningSession tests.

* KnobSpace derivation from adapter metadata (grids, overrides, 2-D planes).
* Golden equivalence: the deprecated per-family tuner shims reproduce the
  session's chosen knobs and estimates exactly; the session itself matches a
  hand-rolled estimate_grid argmin (the legacy tuner body).
* Satellites: no construction for budget-infeasible RMI branches; the joint
  (knob x split) search is ONE batched solve with zero per-split model calls
  (structurally asserted); a seek-heavy device objective can flip the chosen
  knob; jointly tuned (eps, radix_bits) beats eps-only RadixSpline tuning.
* Tuner-choice-vs-exhaustive-replay oracle across 3 families x 3 policies.
* Batched mixed-eps kernel == per-branch mixture histograms.
"""
import warnings

import numpy as np
import pytest

from repro.core import cache_models, cam, page_ref
from repro.core.device_models import Affine
from repro.core.replay import replay_windows
from repro.core.session import CostSession, GridCandidate, System
from repro.core.workload import Workload
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, point_workload
from repro.index import rmi as rmi_mod
from repro.index.adapters import PGMAdapter, RMIAdapter, RadixSplineAdapter
from repro.tuning.session import (CDFShopTuner, KnobSpace,
                                  MulticriteriaTuner, PGMBuilder,
                                  RadixSplineBuilder, RMIBuilder,
                                  TableSizeModel, TuningSession, builder_for)

GEOM = cam.CamGeometry()
BUDGET = 1 << 20


@pytest.fixture(scope="module")
def world():
    keys = make_dataset("books", 200_000, seed=1)
    qk, qpos = point_workload(keys, 20_000, WorkloadSpec("w4", seed=3))
    wl = Workload.point(qpos, n=len(keys), query_keys=qk)
    return keys, qk, qpos, wl


@pytest.fixture(scope="module")
def builders(world):
    keys = world[0]
    return {"pgm": PGMBuilder(keys), "rmi": RMIBuilder(keys),
            "radixspline": RadixSplineBuilder(keys)}


# ---------------------------------------------------------------------------
# KnobSpace
# ---------------------------------------------------------------------------

def test_knob_space_from_adapter_metadata():
    space = KnobSpace.from_metadata(PGMAdapter.knob_metadata())
    assert space.names == ("eps",)
    assert len(space.points()) >= 20         # the dense default grid
    assert space.key({"eps": 64}) == 64      # 1-D spaces key by bare value

    rs = KnobSpace.from_metadata(RadixSplineAdapter.knob_metadata())
    assert rs.names == ("eps", "radix_bits")   # radix_bits IS tunable now
    pts = rs.points()
    n_eps = len(rs.knobs[0].values)
    n_bits = len(rs.knobs[1].values)
    assert len(pts) == n_eps * n_bits          # cartesian product
    assert rs.key(pts[0]) == (pts[0]["eps"], pts[0]["radix_bits"])


def test_knob_space_overrides():
    space = KnobSpace.from_metadata(RadixSplineAdapter.knob_metadata(),
                                    overrides={"eps": (32, 128),
                                               "radix_bits": 12})
    assert [p for p in space.points()] == [
        {"eps": 32, "radix_bits": 12}, {"eps": 128, "radix_bits": 12}]
    with pytest.raises(ValueError, match="unknown knobs"):
        KnobSpace.from_metadata(PGMAdapter.knob_metadata(),
                                overrides={"nope": (1,)})


def test_adapter_knobs_declare_grids(world):
    keys = world[0]
    rs = RadixSplineAdapter.build(keys[:20_000], 64, radix_bits=10)
    meta = rs.knobs()
    assert meta["radix_bits"]["tunable"] is True
    assert meta["radix_bits"]["value"] == 10
    assert 10 in meta["radix_bits"]["grid"]


# ---------------------------------------------------------------------------
# Golden equivalence: session == legacy estimate_grid argmin == shims
# ---------------------------------------------------------------------------

EPS_GRID = (16, 64, 256, 1024)


def test_session_matches_legacy_grid_argmin_pgm(world, builders):
    """The CAM tuner must pick exactly what the legacy tuner body (one
    estimate_grid at each knob's full capacity) picks, with identical ios."""
    keys, qk, qpos, wl = world
    builder = builders["pgm"]
    model = builder.size_model()
    session = CostSession(System(GEOM, BUDGET, "lru"))
    cands = [GridCandidate(knob=e, eps=e, size_bytes=float(model(eps=e)))
             for e in EPS_GRID]
    legacy = session.estimate_grid(cands, Workload.point(qpos, n=len(keys)))

    res = TuningSession(System(GEOM, BUDGET, "lru")).tune(
        builder, Workload.point(qpos, n=len(keys)),
        overrides={"eps": EPS_GRID})
    assert res.best_knob == legacy.best_knob
    for e in legacy.estimates:
        assert abs(res.estimates[e].io_per_query
                   - legacy.estimates[e].io_per_query) < 1e-9, e
        assert res.estimates[e].capacity_pages \
            == legacy.estimates[e].capacity_pages


@pytest.mark.parametrize("policy", ["lru", "fifo", "lfu"])
def test_shims_reproduce_session_choices(world, builders, policy):
    """Deprecated tuner entry points are thin delegates: same knob, same io."""
    from repro.tuning.pgm_tuner import cam_tune_pgm
    from repro.tuning.rmi_tuner import cam_tune_rmi
    from repro.tuning.rs_tuner import cam_tune_radixspline

    keys, qk, qpos, wl = world
    ts = TuningSession(System(GEOM, BUDGET, policy))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy_pgm = cam_tune_pgm(keys, qpos, BUDGET, GEOM, policy,
                                  eps_grid=EPS_GRID)
        legacy_rmi = cam_tune_rmi(keys, qpos, qk, BUDGET, GEOM, policy,
                                  branch_grid=(256, 1024, 4096))
        legacy_rs = cam_tune_radixspline(keys, qpos, BUDGET, GEOM, policy,
                                         eps_grid=EPS_GRID, radix_bits=10)
    res_pgm = ts.tune(builders["pgm"], Workload.point(qpos, n=len(keys)),
                      overrides={"eps": EPS_GRID})
    assert legacy_pgm.best_eps == res_pgm.best_knob
    assert abs(legacy_pgm.est_io - res_pgm.est_io) < 1e-9

    res_rmi = ts.tune(builders["rmi"], wl,
                      overrides={"branch": (256, 1024, 4096)})
    assert legacy_rmi.best_branch == res_rmi.best_knob
    assert abs(legacy_rmi.est_io - res_rmi.est_io) < 1e-9
    assert legacy_rmi.best_branch in legacy_rmi.indexes

    rs_builder = RadixSplineBuilder(keys, ref_radix_bits=10)
    res_rs = ts.tune(rs_builder, Workload.point(qpos, n=len(keys)),
                     overrides={"eps": EPS_GRID, "radix_bits": 10})
    assert legacy_rs.best_eps == res_rs.best["eps"]
    assert abs(legacy_rs.est_io - res_rs.est_io) < 1e-9


def test_baseline_shims_match_session_strategies(world):
    from repro.tuning.pgm_tuner import multicriteria_pgm_tune
    from repro.tuning.rmi_tuner import cdfshop_tune_rmi

    keys, qk, qpos, wl = world
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eps, _ = multicriteria_pgm_tune(keys, index_space_budget=BUDGET // 2,
                                        eps_grid=EPS_GRID)
        branch, _, built = cdfshop_tune_rmi(keys, BUDGET // 2,
                                            branch_grid=(256, 1024, 4096))
    ts = TuningSession(System(GEOM, BUDGET, "lru"))
    res = ts.tune(PGMBuilder(keys), wl, tuner=MulticriteriaTuner(),
                  overrides={"eps": EPS_GRID})
    assert res.best_knob == eps and res.tuner == "multicriteria"
    res2 = ts.tune(RMIBuilder(keys), wl, tuner=CDFShopTuner(),
                   overrides={"branch": (256, 1024, 4096)})
    assert res2.best_knob == branch and res2.tuner == "cdfshop"
    assert branch in built


def test_multicriteria_fallback_picks_coarsest_regardless_of_grid_order(
        world):
    """Legacy fallback semantics: when NOTHING fits the reserved index
    space, multicriteria takes the coarsest (smallest-footprint) candidate,
    not a grid-position-dependent one."""
    keys, _, _, wl = world
    ts = TuningSession(System(GEOM, 2 * 1024, "lru"))   # 1 KiB index space
    res = ts.tune(PGMBuilder(keys), wl, tuner=MulticriteriaTuner(),
                  overrides={"eps": (16, 4, 8)})        # scrambled grid
    assert res.best_knob == 16                          # max eps = coarsest


def test_multicriteria_looser_space_not_less_accurate(world):
    """Legacy property: a looser index-space budget never picks a LESS
    accurate (larger-eps) configuration."""
    keys, _, _, wl = world
    ts_tight = TuningSession(System(GEOM, 2 * (64 << 10), "lru"))
    ts_loose = TuningSession(System(GEOM, 2 * (8 << 20), "lru"))
    tight = ts_tight.tune(PGMBuilder(keys), wl, tuner=MulticriteriaTuner())
    loose = ts_loose.tune(PGMBuilder(keys), wl, tuner=MulticriteriaTuner())
    assert loose.best["eps"] <= tight.best["eps"]


# ---------------------------------------------------------------------------
# Satellite: no construction for infeasible candidates
# ---------------------------------------------------------------------------

def test_infeasible_rmi_branch_never_built(world, monkeypatch):
    keys, qk, qpos, wl = world
    built = []
    real_build = rmi_mod.build_rmi

    def counting_build(k, branch):
        built.append(branch)
        return real_build(k, branch)

    monkeypatch.setattr(rmi_mod, "build_rmi", counting_build)
    # 256 KiB budget: branch 65536 needs ~1.5 MiB -> infeasible, branch
    # 16384 needs ~393 KiB -> infeasible too; only 1024 fits.
    ts = TuningSession(System(GEOM, 256 << 10, "lru"))
    res = ts.tune(RMIBuilder(keys), wl,
                  overrides={"branch": (1024, 16384, 65536)})
    assert built == [1024]                       # ONLY the feasible branch
    assert res.best_knob == 1024
    skipped = {s.knob: s.reason for s in res.skipped}
    assert set(skipped) == {16384, 65536}
    assert "footprint leaves no buffer page" in skipped[65536]
    # the analytic size model is exact, so the skip decision is sound
    assert rmi_mod.rmi_size_bytes(65536) > 256 << 10


# ---------------------------------------------------------------------------
# Satellite: joint (knob x split) search — zero per-split model calls
# ---------------------------------------------------------------------------

def test_joint_split_search_is_one_batched_solve(world, monkeypatch):
    keys, qk, qpos, wl = world
    solves = []
    real_grid = cache_models.hit_rate_grid

    def counting_grid(*a, **kw):
        solves.append(1)
        return real_grid(*a, **kw)

    def no_single_estimates(*a, **kw):
        raise AssertionError("per-candidate estimate called during tuning")

    def no_single_hit_rate(*a, **kw):
        raise AssertionError("single hit-rate solve called during tuning")

    monkeypatch.setattr(cache_models, "hit_rate_grid", counting_grid)
    monkeypatch.setattr(CostSession, "estimate", no_single_estimates)
    monkeypatch.setattr(cache_models, "hit_rate", no_single_hit_rate)

    counts = {}
    for label, splits in (("coarse", (0.5,)),
                          ("fine", tuple(i / 16 for i in range(1, 16)))):
        solves.clear()
        ts = TuningSession(System(GEOM, BUDGET, "lru"), splits=splits)
        res = ts.tune(PGMBuilder(keys), wl, overrides={"eps": EPS_GRID})
        counts[label] = len(solves)
        assert res.batched_solves == 1
        # the table really enumerates the splits (knob rows grew)
        assert all(len(v) >= 1 for v in res.table.values())
    # 15 splits cost exactly as many cache-model solves as 1 split
    assert counts["coarse"] == counts["fine"] == 1


def test_custom_objective_runs_on_table_and_prefers_frugal_split(world):
    keys, qk, qpos, wl = world

    def frugal(point, e):
        # penalize buffer bytes: io + lambda * buffer footprint
        return e.io + 2e-6 * e.capacity_pages * GEOM.page_bytes

    ts = TuningSession(System(GEOM, BUDGET, "lru"))
    res = ts.tune(PGMBuilder(keys), wl, objective=frugal,
                  overrides={"eps": (64, 256)})
    max_split = res.table[res.best_knob][0].split
    assert res.split < max_split              # picked a sub-maximal split
    assert res.objective == "frugal"
    assert res.batched_solves == 1


# ---------------------------------------------------------------------------
# Satellite: device-model-aware objective
# ---------------------------------------------------------------------------

def test_seconds_objective_can_flip_the_chosen_knob(world):
    """Under a seek-heavy device (per-op setup dominating transfer), the
    objective counts miss EVENTS, not pages — so it tolerates a larger eps
    (bigger DAC, better hit rate) that the raw-io objective rejects."""
    keys, qk, qpos, wl = world
    grid = (8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)
    ts = TuningSession(System(GEOM, BUDGET, "lru", device=Affine(alpha=0.01)))
    builder = PGMBuilder(keys)
    res_io = ts.tune(builder, wl, objective="io", overrides={"eps": grid})
    res_s = ts.tune(builder, wl, objective="seconds",
                    overrides={"eps": grid})
    assert res_io.best_knob != res_s.best_knob
    # each winner is optimal under its own metric
    t_io = {k: v[0] for k, v in res_io.table.items()}
    assert res_s.objective_value <= t_io[res_io.best_knob].seconds + 1e-12
    assert res_io.est_io <= res_s.table[res_s.best_knob][0].io + 1e-12


# ---------------------------------------------------------------------------
# Satellite: RadixSpline radix_bits tuned for real
# ---------------------------------------------------------------------------

def test_joint_radix_bits_beats_eps_only(world):
    """Under a tight shared budget, freeing radix-table bytes buys buffer
    pages: the jointly tuned (eps, radix_bits) strictly beats eps-only
    tuning at the legacy fixed radix_bits=16."""
    keys, qk, qpos, wl = world
    budget = 640 << 10
    ts = TuningSession(System(GEOM, budget, "lru"))
    builder = RadixSplineBuilder(keys)
    eps_grid = (32, 64, 128, 256, 512, 1024)
    eps_only = ts.tune(builder, wl,
                       overrides={"eps": eps_grid, "radix_bits": 16})
    joint = ts.tune(builder, wl,
                    overrides={"eps": eps_grid,
                               "radix_bits": (8, 10, 12, 14, 16)})
    assert joint.best["radix_bits"] < 16
    assert joint.est_io < eps_only.est_io
    assert joint.capacity_pages > eps_only.capacity_pages


# ---------------------------------------------------------------------------
# Tuner choice vs exhaustive replay (3 families x 3 policies)
# ---------------------------------------------------------------------------

_ORACLE_GRIDS = {
    "pgm": {"eps": (16, 64, 256, 1024)},
    "rmi": {"branch": (256, 1024, 4096)},
    "radixspline": {"eps": (32, 128, 512), "radix_bits": (10, 16)},
}


@pytest.mark.parametrize("family", sorted(_ORACLE_GRIDS))
@pytest.mark.parametrize("policy", ["lru", "fifo", "lfu"])
def test_tuner_choice_vs_exhaustive_replay(world, builders, family, policy):
    """The chosen knob's REPLAYED I/O must be within 10% of the replay-best
    knob across the grid — the estimates may be approximate, the decision
    must not be."""
    keys, qk, qpos, wl = world
    builder = builders[family]
    ts = TuningSession(System(GEOM, BUDGET, policy))
    res = ts.tune(builder, wl, overrides=_ORACLE_GRIDS[family],
                  sample_rate=0.5)
    replayed = {}
    space = builder.knob_space(_ORACLE_GRIDS[family])
    for point in space.points():
        knob = space.key(point)
        if knob not in res.estimates:
            continue
        adapter = builder.build(point)
        cap = ts.system.capacity_for(adapter.size_bytes)
        if cap < 1:
            continue
        plo, phi = adapter.probe_windows(qk, GEOM)
        replayed[knob] = float(replay_windows(plo, phi, cap, policy).mean())
    assert res.best_knob in replayed, (family, policy)
    best_actual = min(replayed.values())
    assert replayed[res.best_knob] <= 1.10 * best_actual, \
        (family, policy, replayed, res.best_knob)


# ---------------------------------------------------------------------------
# Batched mixed-eps kernel
# ---------------------------------------------------------------------------

def test_mixed_eps_grid_kernel_matches_per_branch(world):
    keys, qk, qpos, wl = world
    num_pages = GEOM.num_pages(len(keys))
    adapters = [RMIAdapter.build(keys, b) for b in (256, 1024, 4096)]
    eps_rows = np.stack([a.point_ref_eps(wl, GEOM)[0] for a in adapters])
    counts, totals = page_ref.point_page_refs_mixed_eps_grid(
        qpos, eps_rows, GEOM.c_ipp, num_pages)
    for i, a in enumerate(adapters):
        ref_counts, ref_total = page_ref.point_page_refs_mixed_eps(
            qpos, eps_rows[i], GEOM.c_ipp, num_pages)
        assert np.abs(counts[i] - np.asarray(ref_counts)).max() < 5e-2
        assert abs(totals[i] - float(ref_total)) < 1e-3 * float(ref_total)


def test_mixed_eps_grid_kernel_chunked_path(world):
    """Wide-window classes must chunk without changing the histograms."""
    keys, qk, qpos, wl = world
    num_pages = GEOM.num_pages(len(keys))
    a = RMIAdapter.build(keys, 64)          # tiny branch -> huge leaf eps
    eps_rows = a.point_ref_eps(wl, GEOM)[0][None, :]
    full, t_full = page_ref.point_page_refs_mixed_eps_grid(
        qpos, eps_rows, GEOM.c_ipp, num_pages)
    old = page_ref._SCRATCH_ENTRIES
    try:
        page_ref._SCRATCH_ENTRIES = 4096
        chunked, t_chunk = page_ref.point_page_refs_mixed_eps_grid(
            qpos, eps_rows, GEOM.c_ipp, num_pages)
    finally:
        page_ref._SCRATCH_ENTRIES = old
    np.testing.assert_allclose(chunked, full, atol=1e-4)
    np.testing.assert_allclose(t_chunk, t_full, rtol=1e-9)


def test_mixed_eps_grid_many_nonpow2_classes(world):
    """Regression: >256 distinct NON-pow2 eps classes must not wrap the
    class codes (uint8) and merge unrelated classes."""
    keys, qk, qpos, wl = world
    num_pages = GEOM.num_pages(len(keys))
    rng = np.random.default_rng(7)
    eps_rows = rng.choice(np.arange(3, 603, 2), size=(2, 2000))  # 300 classes
    pos = qpos[:2000]
    counts, totals = page_ref.point_page_refs_mixed_eps_grid(
        pos, eps_rows, GEOM.c_ipp, num_pages)
    for i in range(2):
        ref_counts, ref_total = page_ref.point_page_refs_mixed_eps(
            pos, eps_rows[i], GEOM.c_ipp, num_pages)
        assert np.abs(counts[i] - np.asarray(ref_counts)).max() < 1e-2, i
        assert abs(totals[i] - float(ref_total)) < 1e-3 * float(ref_total)


@pytest.mark.parametrize("policy", ["lru", "lfu"])
def test_estimate_grid_mixed_eps_flag_equivalent(world, policy):
    """batch_mixed_eps=True (grouped kernel) == False (per-branch path)."""
    keys, qk, qpos, wl = world
    session = CostSession(System(GEOM, BUDGET, policy))
    cands = [GridCandidate(knob=b, size_bytes=rmi_mod.rmi_size_bytes(b),
                           index=RMIAdapter.build(keys, b))
             for b in (256, 1024, 4096)]
    batched = session.estimate_grid(cands, wl, batch_mixed_eps=True)
    legacy = session.estimate_grid(cands, wl, batch_mixed_eps=False)
    assert batched.best_knob == legacy.best_knob
    for b in legacy.estimates:
        assert abs(batched.estimates[b].hit_rate
                   - legacy.estimates[b].hit_rate) < 1e-4, (b, policy)
        assert batched.estimates[b].capacity_pages \
            == legacy.estimates[b].capacity_pages


# ---------------------------------------------------------------------------
# Misc session behavior
# ---------------------------------------------------------------------------

def test_budget_override_and_builder_registry(world):
    keys, qk, qpos, wl = world
    ts = TuningSession(System(GEOM, 64 << 20, "lru"))
    builder = builder_for("pgm", keys)
    wide = ts.tune(builder, wl, overrides={"eps": EPS_GRID})
    tight = ts.tune(builder, wl, budget=BUDGET, overrides={"eps": EPS_GRID})
    assert tight.capacity_pages < wide.capacity_pages
    assert builder_for("btree", keys) is not None  # registered in PR 10
    with pytest.raises(ValueError, match="unknown index family"):
        builder_for("lsm", keys)


def test_table_size_model_override(world):
    keys, qk, qpos, wl = world
    adapters = {e: PGMAdapter.build(keys, e) for e in (64, 256)}
    exact = TableSizeModel({e: float(a.size_bytes)
                            for e, a in adapters.items()})
    ts = TuningSession(System(GEOM, BUDGET, "lru"))
    res = ts.tune(PGMBuilder(keys), wl, overrides={"eps": (64, 256)},
                  size_model=exact)
    for e, a in adapters.items():
        assert res.estimates[e].capacity_pages \
            == ts.system.capacity_for(a.size_bytes)


def test_infeasible_everything_raises(world):
    keys, qk, qpos, wl = world
    ts = TuningSession(System(GEOM, 8 << 10, "lru"))
    with pytest.raises(ValueError, match="memory budget too small"):
        ts.tune(PGMBuilder(keys), wl, overrides={"eps": (8,)})


# ---------------------------------------------------------------------------
# Eviction policy as a knob
# ---------------------------------------------------------------------------

def test_policy_knob_joins_the_search(world, builders):
    """tune(policies=...) crosses the table with the policy axis: the
    result's best point names a policy, every estimate carries its policy,
    and the winner reproduces the best of three single-policy tunes."""
    keys, qk, qpos, wl = world
    res = TuningSession(System(GEOM, BUDGET, "lru")).tune(
        builders["pgm"], wl, overrides={"eps": (16, 64, 256)},
        policies=("lru", "fifo", "lfu"))
    assert res.best["policy"] in ("lru", "fifo", "lfu")
    assert res.batched_solves == 1               # still ONE engine call
    assert len(res.estimates) == 3 * 3           # (policy x eps) plane

    singles = {}
    for pol in ("lru", "fifo", "lfu"):
        r = TuningSession(System(GEOM, BUDGET, pol)).tune(
            builders["pgm"], wl, overrides={"eps": (16, 64, 256)})
        singles[pol] = r
        # the (pol, eps) sub-plane reprices the single-policy tune exactly
        for kn, est in r.estimates.items():
            joint = res.estimates[(pol, kn)]
            assert joint.io_per_query == pytest.approx(est.io_per_query,
                                                       abs=1e-12), (pol, kn)
            assert joint.policy == pol
    best_io = min(s.estimates[s.best_knob].io_per_query
                  for s in singles.values())
    assert res.estimates[res.best_knob].io_per_query \
        == pytest.approx(best_io, abs=1e-12)
    winners = {p for p, s in singles.items()
               if s.estimates[s.best_knob].io_per_query
               == pytest.approx(best_io, abs=1e-12)}
    assert res.best["policy"] in winners


def test_policy_knob_rejects_custom_tuner_combo(world, builders):
    keys, qk, qpos, wl = world
    from repro.tuning.session import CamTuner
    with pytest.raises(ValueError, match="policies"):
        TuningSession(System(GEOM, BUDGET, "lru")).tune(
            builders["pgm"], wl, tuner=CamTuner(),
            policies=("lru", "fifo"))
