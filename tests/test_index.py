"""Learned-index substrate tests: ε guarantee, recursion, RMI windows,
replay buffers, disk layout."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import replay
from repro.data.datasets import make_dataset
from repro.index import disk_layout, pgm, pla, rmi


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=2, max_value=256),          # eps
    st.sampled_from(["books", "fb", "osm", "wiki"]),
    st.integers(min_value=0, max_value=1000),
)
def test_pla_eps_guarantee(eps, dataset, seed):
    keys = make_dataset(dataset, 20_000, seed=seed)
    seg = pla.build_pla(keys, eps)
    pred = pla.predict_pla(seg, keys, len(keys))
    err = np.abs(pred - np.arange(len(keys)))
    assert err.max() <= eps, (dataset, eps, int(err.max()))


def test_pla_segment_count_decreases_with_eps():
    keys = make_dataset("books", 100_000, seed=2)
    counts = [len(pla.build_pla(keys, e)) for e in (4, 16, 64, 256)]
    assert counts == sorted(counts, reverse=True)
    assert counts[-1] >= 1


def test_pgm_recursion_and_size():
    keys = make_dataset("osm", 200_000, seed=3)
    idx = pgm.build_pgm(keys, eps=32)
    assert len(idx.levels[-1]) == 1            # recursion reaches a single root
    assert idx.size_bytes == 16 * sum(len(l) for l in idx.levels)
    pred = idx.predict(keys)
    assert np.abs(pred - np.arange(len(keys))).max() <= 32


def test_pgm_window_contains_true_position():
    keys = make_dataset("fb", 50_000, seed=4)
    idx = pgm.build_pgm(keys, eps=16)
    rng = np.random.default_rng(0)
    sample = rng.choice(len(keys), 5000, replace=False)
    lo, hi = idx.window(keys[sample])
    assert np.all(lo <= sample) and np.all(sample <= hi)


def test_rmi_window_contains_true_position():
    keys = make_dataset("wiki", 50_000, seed=5)
    idx = rmi.build_rmi(keys, branch=256)
    rng = np.random.default_rng(1)
    sample = rng.choice(len(keys), 5000, replace=False)
    lo, hi, eps_q = idx.window(keys[sample])
    assert np.all(lo <= sample) and np.all(sample <= hi)
    w = idx.leaf_weights(keys[sample])
    assert abs(w.sum() - 1.0) < 1e-9


def test_rmi_error_shrinks_with_branch():
    keys = make_dataset("books", 100_000, seed=6)
    mean_eps = [rmi.build_rmi(keys, b).leaf_eps.mean() for b in (64, 512, 4096)]
    assert mean_eps[0] > mean_eps[1] > mean_eps[2]


# ---------------------------------------------------------------------------
# Replay buffers — hand-crafted policy behaviour
# ---------------------------------------------------------------------------

def test_lru_evicts_least_recent():
    buf = replay.LRUBuffer(2)
    assert not buf.access(1) and not buf.access(2)
    assert buf.access(1)          # 1 most recent
    assert not buf.access(3)      # evicts 2
    assert 2 not in buf and 1 in buf


def test_fifo_evicts_arrival_order_despite_reuse():
    buf = replay.FIFOBuffer(2)
    buf.access(1); buf.access(2); buf.access(1)   # reuse does NOT refresh FIFO
    assert not buf.access(3)                      # evicts 1 (oldest arrival)
    assert 1 not in buf and 2 in buf


def test_lfu_keeps_frequent_page():
    buf = replay.LFUBuffer(2)
    for _ in range(5):
        buf.access(1)
    buf.access(2)
    assert not buf.access(3)      # evicts 2 (freq 1), never 1 (freq 5)
    assert 1 in buf and 2 not in buf


def test_cyclic_pattern_thrashes_lru_fifo():
    """Belady's classic: cyclic scan of C+1 pages gives 0 hits for LRU/FIFO."""
    trace = list(range(5)) * 20
    for policy in ("lru", "fifo"):
        hits, _ = replay.replay_refs(trace, capacity=4, policy=policy)
        assert hits == 0, policy


def test_lfu_pins_hot_page_in_skewed_cycle():
    """LFU retains the high-frequency page where the cycle exceeds capacity."""
    trace = [0, 1, 0, 2, 0, 3, 0, 4] * 20
    hits_lfu, _ = replay.replay_refs(trace, capacity=2, policy="lfu")
    # page 0 has freq ~half the trace; after warmup every access to 0 hits.
    assert hits_lfu >= len(trace) // 2 - 4


# ---------------------------------------------------------------------------
# Disk layout / fetch strategies
# ---------------------------------------------------------------------------

def test_fetch_strategy_page_counts():
    layout = disk_layout.PageLayout(c_ipp=10, page_bytes=160)
    lo = np.array([0, 95, 38])
    hi = np.array([9, 105, 61])
    plo, phi = disk_layout.fetch_all_at_once(lo, hi, layout)
    np.testing.assert_array_equal(plo, [0, 9, 3])
    np.testing.assert_array_equal(phi, [0, 10, 6])
    true = np.array([5, 103, 59])
    counts = disk_layout.fetch_one_by_one_counts(lo, true, layout)
    np.testing.assert_array_equal(counts, [1, 2, 3])


def test_radixspline_error_guarantee_and_cam():
    """RadixSpline (third index family): corridor guarantees |err| <= eps,
    and the SAME CAM estimators apply (index-agnosticism, paper property i)."""
    from repro.core import cam
    from repro.core.qerror import q_error
    from repro.index.radixspline import build_radixspline

    keys = make_dataset("wiki", 100_000, seed=8)
    eps = 32
    idx = build_radixspline(keys, eps)
    pred = idx.predict(keys)
    err = np.abs(pred - np.arange(len(keys)))
    assert err.max() <= eps

    from repro.data.workloads import WorkloadSpec, point_workload

    qk, qpos = point_workload(keys, 20_000, WorkloadSpec("w4", seed=4))
    geom = cam.CamGeometry()
    budget = 1 << 20
    est = cam.estimate_point_io(qpos, eps, len(keys), geom, budget,
                                idx.size_bytes, policy="lru")
    lo, hi = idx.window(qk)
    cap = max(1, (budget - idx.size_bytes) // geom.page_bytes)
    misses = replay.replay_windows(lo // geom.c_ipp, hi // geom.c_ipp,
                                   cap, "lru")
    assert float(q_error(est.io_per_query, misses.mean())) < 1.3


def test_clock_policy_between_fifo_and_lru():
    """CLOCK (policy pluggability beyond the paper): second-chance behavior
    on a skewed IID trace lands between FIFO and LRU hit rates."""
    rng = np.random.default_rng(5)
    p = 1.0 / np.arange(1, 2001) ** 1.3
    p /= p.sum()
    trace = rng.choice(2000, size=60_000, p=p)
    rates = {}
    for policy in ("fifo", "clock", "lru"):
        hits, _ = replay.replay_refs(trace, capacity=300, policy=policy)
        rates[policy] = hits / len(trace)
    assert rates["fifo"] - 0.02 <= rates["clock"] <= rates["lru"] + 0.02


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=4, max_value=128),
    st.sampled_from(["books", "fb", "osm", "wiki"]),
    st.integers(min_value=0, max_value=500),
)
def test_radixspline_guarantee_sweep(eps, dataset, seed):
    from repro.index.radixspline import build_radixspline

    keys = make_dataset(dataset, 10_000, seed=seed)
    idx = build_radixspline(keys, eps)
    err = np.abs(idx.predict(keys) - np.arange(len(keys)))
    assert err.max() <= eps, (dataset, eps, int(err.max()))


def test_clock_second_chance_behavior():
    buf = replay.CLOCKBuffer(2)
    assert not buf.access(1) and not buf.access(2)
    assert buf.access(1)          # sets 1's ref bit
    buf.access(1)
    assert not buf.access(3)      # hand clears bits; evicts 2 eventually
    assert 3 in buf
