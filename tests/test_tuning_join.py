"""Tuning + hybrid-join tests: U-curve, budget feasibility, Algorithm 2
invariants, executor correctness vs numpy join oracle."""
import numpy as np
import pytest

from repro.core import cam
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, point_workload, join_outer_keys
from repro.index.disk_layout import PageLayout
from repro.index.pgm import build_pgm
from repro.join.calibrate import calibrate
from repro.join.executors import hybrid_join, inlj, point_only, range_only
from repro.join.hybrid import JoinCostParams, partition_probes
from repro.core.session import System
from repro.core.workload import Workload
from repro.tuning.fit import fit_power_law
from repro.tuning.session import (CDFShopTuner, MulticriteriaTuner,
                                  PGMBuilder, RMIBuilder, TuningSession)


@pytest.fixture(scope="module")
def setup():
    keys = make_dataset("books", 500_000, seed=1)
    spec = WorkloadSpec("w4", seed=3)
    qk, qpos = point_workload(keys, 30_000, spec)
    return keys, qk, qpos


def test_power_law_fit_recovers_params():
    eps = np.array([8, 16, 32, 64, 128, 256, 512])
    truth = 3e7 * eps ** -1.1 + 5e3
    fitted = fit_power_law(eps, truth)
    pred = fitted(eps)
    assert np.max(np.abs(pred - truth) / truth) < 0.05


def test_cam_tune_pgm_respects_budget(setup):
    keys, qk, qpos = setup
    M = 2 << 20
    session = TuningSession(System(cam.CamGeometry(), M, "lru"))
    res = session.tune(PGMBuilder(keys), Workload.point(qpos, n=len(keys)),
                       sample_rate=0.5)
    assert res.best_knob in res.estimates
    assert float(res.size_model(eps=res.best_knob)) < M
    # every evaluated candidate left room for at least one buffer page
    for e, est in res.estimates.items():
        assert est.capacity_pages >= 0


def test_cam_tune_pgm_ucurve_under_tight_budget(setup):
    """With a tight budget the cost curve must rise at BOTH extremes
    (tiny eps → index starves the buffer; huge eps → DAC dominates)."""
    keys, qk, qpos = setup
    M = int(1.2 * 2**20)
    session = TuningSession(System(cam.CamGeometry(), M, "lru"))
    res = session.tune(
        PGMBuilder(keys), Workload.point(qpos, n=len(keys)),
        overrides={"eps": (8, 16, 32, 64, 128, 256, 512, 1024, 2048)})
    ios = {e: est.io_per_query for e, est in res.estimates.items()}
    eps_sorted = sorted(ios)
    best = res.best_knob
    assert ios[eps_sorted[-1]] > ios[best]  # right arm rises (DAC dominates)
    assert best != eps_sorted[-1]


def test_multicriteria_returns_smallest_feasible(setup):
    keys, _, qpos = setup
    wl = Workload.point(qpos, n=len(keys))
    builder = PGMBuilder(keys)
    tight = TuningSession(System(cam.CamGeometry(), 2 * (64 << 10), "lru")) \
        .tune(builder, wl, tuner=MulticriteriaTuner())
    loose = TuningSession(System(cam.CamGeometry(), 2 * (8 << 20), "lru")) \
        .tune(builder, wl, tuner=MulticriteriaTuner())
    assert loose.best_knob <= tight.best_knob  # looser space → more accurate


def test_cam_tune_rmi_runs(setup):
    keys, qk, qpos = setup
    session = TuningSession(System(cam.CamGeometry(), 2 << 20, "lru"))
    builder = RMIBuilder(keys)
    res = session.tune(builder,
                       Workload.point(qpos, n=len(keys), query_keys=qk),
                       overrides={"branch": (256, 1024, 4096)},
                       sample_rate=0.5)
    assert res.best_knob in (256, 1024, 4096)
    cdf = TuningSession(System(cam.CamGeometry(), 2 << 20, "lru")).tune(
        builder, Workload.point(qpos, n=len(keys), query_keys=qk),
        tuner=CDFShopTuner(), overrides={"branch": (256, 1024, 4096)})
    assert cdf.best_knob in builder.built


# ---------------------------------------------------------------------------
# Algorithm 2
# ---------------------------------------------------------------------------

def test_partition_covers_stream_without_overlap():
    rng = np.random.default_rng(0)
    lo = np.sort(rng.integers(0, 5000, size=3000))
    hi = lo + rng.integers(0, 3, size=3000)
    segs = partition_probes(lo, hi, JoinCostParams(), n_min=64, k_max=512)
    assert segs[0].start == 0 and segs[-1].end == 3000
    for a, b in zip(segs, segs[1:]):
        assert a.end == b.start
    for s in segs:
        assert s.page_lo <= s.page_hi
        assert s.n_keys == s.end - s.start


def test_partition_dense_region_uses_range():
    """A dense run of probes (every page hit repeatedly) must flip to range
    probing; an extremely sparse run must stay point probing."""
    dense_lo = np.repeat(np.arange(200), 40)        # 8000 probes over 200 pages
    dense_hi = dense_lo
    segs = partition_probes(dense_lo, dense_hi, JoinCostParams(), n_min=64, k_max=10**9)
    assert any(s.use_range for s in segs)
    sparse_lo = np.arange(0, 3_000_000, 5000)       # 1 probe per 5000 pages
    segs = partition_probes(sparse_lo, sparse_lo, JoinCostParams(), n_min=64, k_max=10**9)
    assert not any(s.use_range for s in segs)


# ---------------------------------------------------------------------------
# Join executors
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def join_setup():
    keys = make_dataset("books", 300_000, seed=5)
    idx = build_pgm(keys, eps=32)
    outer = join_outer_keys(keys, 20_000, WorkloadSpec("w4", seed=9))
    layout = PageLayout()
    capacity = (2 << 20) // layout.page_bytes
    return keys, idx, outer, layout, capacity


def test_all_strategies_same_matches(join_setup):
    keys, idx, outer, layout, cap = join_setup
    oracle = int(np.isin(outer, keys).sum())
    for fn in (inlj, point_only, range_only):
        st = fn(idx, keys, outer, layout, cap)
        assert st.matches == oracle, st.strategy
    st = hybrid_join(idx, keys, outer, layout, cap, n_min=128)
    assert st.matches == oracle


def test_sorted_probing_beats_unsorted(join_setup):
    keys, idx, outer, layout, cap = join_setup
    st_inlj = inlj(idx, keys, outer, layout, cap)
    st_point = point_only(idx, keys, outer, layout, cap)
    assert st_point.physical_ios <= st_inlj.physical_ios
    assert st_point.seconds <= st_inlj.seconds


def test_hybrid_not_worse_than_both_pure(join_setup):
    keys, idx, outer, layout, cap = join_setup
    params = calibrate(idx, keys, layout, cap)
    st_p = point_only(idx, keys, outer, layout, cap)
    st_r = range_only(idx, keys, outer, layout, cap)
    st_h = hybrid_join(idx, keys, outer, layout, cap, params=params, n_min=128)
    assert st_h.seconds <= 1.15 * min(st_p.seconds, st_r.seconds)


def test_calibration_recovers_machine_constants(join_setup):
    keys, idx, _, layout, cap = join_setup
    from repro.sim.machine import MachineParams

    machine = MachineParams()
    params = calibrate(idx, keys, layout, cap, machine=machine)
    assert abs(params.lambda_point - machine.miss_latency_point) / machine.miss_latency_point < 0.05
    assert abs(params.lambda_range - machine.miss_latency_range) / machine.miss_latency_range < 0.05
    assert abs(params.beta - machine.cpu_per_page_scan) / machine.cpu_per_page_scan < 0.15
