"""Hit-rate model tests: fixed-point consistency, IRM validation vs replay,
and the sorted-workload theorem (exact, property-based)."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.core import cache_models as cm
from repro.core import page_ref
from repro.core import replay


def zipf_probs(n, a=1.2, seed=0):
    p = 1.0 / np.arange(1, n + 1) ** a
    rng = np.random.default_rng(seed)
    rng.shuffle(p)
    return p / p.sum()


# ---------------------------------------------------------------------------
# Fixed-point consistency
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cap_frac", [0.05, 0.3, 0.7])
def test_che_consistency(cap_frac):
    probs = jnp.asarray(zipf_probs(5000), jnp.float32)
    cap = cap_frac * 5000
    t = cm.solve_che_time(probs, cap)
    lhs = float(jnp.sum(-jnp.expm1(-probs * t)))
    assert abs(lhs - cap) / cap < 1e-3


@pytest.mark.parametrize("cap_frac", [0.05, 0.3, 0.7])
def test_fifo_consistency(cap_frac):
    probs = jnp.asarray(zipf_probs(5000), jnp.float32)
    cap = cap_frac * 5000
    tau = cm.solve_fifo_tau(probs, cap)
    occ = probs * tau / (1.0 - probs + probs * tau)
    assert abs(float(jnp.sum(occ)) - cap) / cap < 1e-3


def test_hit_rates_bounded_and_ordered():
    """LFU >= LRU >= FIFO under IRM for skewed popularity (classic result)."""
    probs = jnp.asarray(zipf_probs(2000, a=1.5), jnp.float32)
    cap = 200
    h_lfu = float(cm.hit_rate_lfu(probs, cap))
    h_lru = float(cm.hit_rate_lru(probs, cap))
    h_fifo = float(cm.hit_rate_fifo(probs, cap))
    for h in (h_lfu, h_lru, h_fifo):
        assert 0.0 <= h <= 1.0
    assert h_lfu >= h_lru - 1e-3
    assert h_lru >= h_fifo - 1e-3


def test_uniform_popularity_all_policies_equal():
    n, cap = 1000, 100
    probs = jnp.full((n,), 1.0 / n, jnp.float32)
    for fn in (cm.hit_rate_lru, cm.hit_rate_fifo, cm.hit_rate_lfu):
        assert abs(float(fn(probs, cap)) - cap / n) < 0.02


# ---------------------------------------------------------------------------
# IRM estimators vs actual replay of an IID trace
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["lru", "fifo", "lfu"])
def test_irm_estimate_matches_iid_replay(policy):
    n_pages, cap, n_refs = 2000, 300, 120_000
    probs = zipf_probs(n_pages, a=1.3, seed=1)
    rng = np.random.default_rng(2)
    trace = rng.choice(n_pages, size=n_refs, p=probs)
    hits, misses = replay.replay_refs(trace, cap, policy)
    actual = hits / n_refs
    est = float(cm.hit_rate(policy, cap, jnp.asarray(probs, jnp.float32),
                            total_requests=n_refs))
    # LFU converges slowly on finite traces (paper §VII-C caveat) — wider tol.
    tol = 0.08 if policy == "lfu" else 0.03
    assert abs(est - actual) < tol, (policy, est, actual)


def test_compulsory_case_large_capacity():
    n_pages, n_refs = 500, 20_000
    probs = zipf_probs(n_pages, a=1.1, seed=3)
    rng = np.random.default_rng(4)
    trace = rng.choice(n_pages, size=n_refs, p=probs)
    distinct = len(np.unique(trace))
    hits, _ = replay.replay_refs(trace, capacity=n_pages + 10, policy="lru")
    est = float(cm.hit_rate("lru", n_pages + 10, jnp.asarray(probs, jnp.float32),
                            total_requests=n_refs, distinct_pages=distinct))
    assert abs(est - hits / n_refs) < 1e-6


# ---------------------------------------------------------------------------
# Theorem III.1 — sorted workloads: h == (R - N)/R, policy-independent, EXACT
# ---------------------------------------------------------------------------

def _sorted_windows(eps, c_ipp, n_queries, seed, n=50_000):
    rng = np.random.default_rng(seed)
    pos = np.sort(rng.integers(0, n, size=n_queries))
    pred = np.clip(pos + rng.integers(-eps, eps + 1, size=n_queries), 0, n - 1)
    lo = np.clip(pred - eps, 0, n - 1) // c_ipp
    hi = np.clip(pred + eps, 0, n - 1) // c_ipp
    # windows of a sorted query stream: enforce monotone window starts, as in
    # the theorem statement (learned-index windows over sorted keys are).
    lo = np.maximum.accumulate(lo)
    hi = np.maximum(hi, lo)
    R = int(np.sum(hi - lo + 1))
    distinct = set()
    for a, b in zip(lo, hi):
        distinct.update(range(a, b + 1))
    return lo, hi, R, len(distinct)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=64),      # eps
    st.integers(min_value=4, max_value=64),      # c_ipp
    st.integers(min_value=20, max_value=300),    # queries
    st.booleans(),                               # lru vs fifo
    st.integers(min_value=0, max_value=10_000),  # seed
)
def test_sorted_workload_theorem_exact_lru_fifo(eps, c_ipp, n_queries, use_lru, seed):
    policy = "lru" if use_lru else "fifo"
    lo, hi, R, N = _sorted_windows(eps, c_ipp, n_queries, seed)
    capacity = 1 + int(np.ceil(2 * eps / c_ipp))
    misses = replay.replay_windows(lo, hi, capacity, policy)
    assert misses.sum() == N  # exactly one compulsory miss per distinct page
    h_actual = (R - misses.sum()) / R
    h_thm = float(cm.hit_rate_compulsory(R, N))
    assert abs(h_actual - h_thm) < 1e-6


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=64),
    st.integers(min_value=4, max_value=64),
    st.integers(min_value=20, max_value=300),
    st.integers(min_value=0, max_value=10_000),
)
def test_sorted_workload_theorem_lfu_caveat(eps, c_ipp, n_queries, seed):
    """FINDING (recorded in EXPERIMENTS.md): Thm III.1 claims policy
    independence, but its proof step "no page in W_t can be evicted before
    pi_t finishes" fails for LFU at the minimal capacity — a stale
    high-frequency page pins itself and LFU evicts the freq-1 in-window page
    (hypothesis found concrete counterexamples, e.g. eps=1, c_ipp=4).  The
    theorem IS a valid lower bound for LFU, and exact given C >= N slack."""
    lo, hi, R, N = _sorted_windows(eps, c_ipp, n_queries, seed)
    capacity = 1 + int(np.ceil(2 * eps / c_ipp))
    misses = replay.replay_windows(lo, hi, capacity, "lfu").sum()
    assert misses >= N                     # compulsory lower bound always holds
    misses_big = replay.replay_windows(lo, hi, N + 1, "lfu").sum()
    assert misses_big == N                 # exact once capacity has slack


def _coverage(lo, hi, num_pages):
    diff = (np.bincount(lo, minlength=num_pages + 1)[:num_pages]
            - np.bincount(hi + 1, minlength=num_pages + 2)[:num_pages])
    return np.cumsum(diff).astype(np.float64)


# ---------------------------------------------------------------------------
# sorted_scan family — the policy-aware sorted-stream model
# ---------------------------------------------------------------------------

def test_sorted_scan_dispatch_regimes():
    lo, hi, R, N = _sorted_windows(16, 8, 400, seed=11)
    num_pages = int(hi.max()) + 1
    cov = _coverage(lo, hi, num_pages)
    kw = dict(total_refs=float(R), distinct_pages=float(N), coverage=cov)
    # thrash: below the Theorem III.1 capacity premise, every ref misses
    assert cm.sorted_scan_misses("lru", 2, min_capacity=5, **kw) == R
    assert cm.sorted_scan_hit_rate("lfu", 2, min_capacity=5, **kw) == 0.0
    # recency policies: compulsory closed form at ANY capacity above premise
    for pol in cm.RECENCY_POLICIES:
        assert cm.sorted_scan_misses(pol, 10, **kw) == N
    # LFU with capacity slack: compulsory too (buffer never evicts a window)
    assert cm.sorted_scan_misses("lfu", N + 5, **kw) == N
    # LFU below N: frequency-aware, bracketed by [N, R], monotone in capacity
    small = cm.sorted_scan_misses("lfu", max(2, N // 10), **kw)
    mid = cm.sorted_scan_misses("lfu", max(3, N // 2), **kw)
    assert N <= mid <= small <= R
    # without a coverage histogram the model degrades to the recency form
    assert cm.sorted_scan_misses(
        "lfu", 10, total_refs=float(R), distinct_pages=float(N)) == N


def test_sorted_scan_zero_guards_match_compulsory():
    """Satellite fix: _finish's old inline form used max(r, 1e-30); the
    shared model must use hit_rate_compulsory's guards everywhere."""
    assert cm.sorted_scan_hit_rate("lru", 8, total_refs=0.0,
                                   distinct_pages=0.0) == 0.0
    assert float(cm.hit_rate_compulsory(0.0, 0.0)) == 0.0
    for r, n in [(0.5, 0.25), (1.0, 1.0), (100.0, 7.0)]:
        assert abs(cm.sorted_scan_hit_rate("fifo", 1_000_000, total_refs=r,
                                           distinct_pages=n)
                   - float(cm.hit_rate_compulsory(r, n))) < 1e-7


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=1, max_value=64),
    st.integers(min_value=4, max_value=64),
    st.integers(min_value=50, max_value=300),
    st.integers(min_value=0, max_value=10_000),
)
def test_sorted_scan_lfu_model_bracketed_vs_replay(eps, c_ipp, n_queries, seed):
    """The frequency-aware form stays within the physical bracket [N, R]
    and never under-cuts the compulsory bound that LFU replay obeys."""
    lo, hi, R, N = _sorted_windows(eps, c_ipp, n_queries, seed)
    num_pages = int(hi.max()) + 1
    cov = _coverage(lo, hi, num_pages)
    cap = max(2, N // 3)
    miss = cm.sorted_scan_misses("lfu", cap, total_refs=float(R),
                                 distinct_pages=float(N), coverage=cov)
    assert N - 1e-3 <= miss <= R + 1e-3
    actual = replay.replay_windows(lo, hi, cap, "lfu").sum()
    assert actual >= N                      # replay obeys the same floor


def test_sorted_scan_grid_matches_scalar():
    lo, hi, R, N = _sorted_windows(32, 16, 500, seed=4)
    num_pages = int(hi.max()) + 1
    cov = jnp.asarray(_coverage(lo, hi, num_pages), jnp.float32)
    caps = np.array([1, 3, 10, N // 2, N + 10], np.float64)
    min_caps = np.full_like(caps, 3.0)
    pinned = 7.0
    for policy in ("lru", "fifo", "lfu"):
        h_grid = np.asarray(cm.sorted_scan_hit_rate_grid(
            policy, jnp.broadcast_to(cov, (len(caps),) + cov.shape),
            jnp.full((len(caps),), float(R)), jnp.full((len(caps),), float(N)),
            jnp.full((len(caps),), pinned), jnp.asarray(caps, jnp.float32),
            jnp.asarray(min_caps, jnp.float32)))
        for i, cap in enumerate(caps):
            h_ref = cm.sorted_scan_hit_rate(
                policy, cap, total_refs=float(R), distinct_pages=float(N),
                coverage=cov, pinned_retouches=pinned, min_capacity=3)
            assert abs(float(h_grid[i]) - h_ref) < 1e-5, (policy, cap)


def test_sorted_scan_lfu_pinned_correction_vs_replay():
    """Satellite fix: the pressure-pinned junction bound removes the ~2x
    LFU over-prediction on strongly recency-like narrow-window streams at
    small capacities (width-2 sliding windows, dense jittered width-1/2
    streams), while never under-cutting replay on those streams."""
    streams = []
    # width-2 stride-1 sliding windows: the canonical over-prediction case
    lo = np.arange(4000, dtype=np.int64)
    streams.append(("slide-w2", lo, lo + 1, [8, 64, 256]))
    # dense jittered width-1/2 stream (many probes per page)
    rng = np.random.default_rng(3)
    pos = np.sort(rng.integers(0, 20_000, size=8000))
    dlo = np.clip(pos - 2, 0, 19_999) // 16
    dhi = np.clip(pos + 2, 0, 19_999) // 16
    dlo = np.maximum.accumulate(dlo)
    streams.append(("dense-jitter", dlo, np.maximum(dhi, dlo), [4, 16, 64]))
    for name, slo, shi, caps in streams:
        num_pages = int(shi.max()) + 1
        r, n, cov, pinned = page_ref.sorted_workload_stats(
            jnp.asarray(slo, jnp.int32), jnp.asarray(shi, jnp.int32),
            num_pages)
        for cap in caps:
            actual = float(replay.replay_windows(slo, shi, cap, "lfu").sum())
            pred = cm.sorted_scan_misses(
                "lfu", cap, total_refs=float(r), distinct_pages=float(n),
                coverage=cov, pinned_retouches=float(pinned),
                min_capacity=int((shi - slo + 1).max()))
            q = max(pred / actual, actual / pred)
            assert q < 1.25, (name, cap, pred, actual)
            # junction re-touches are guaranteed hits: never under-predict
            assert pred >= actual - 1e-6, (name, cap, pred, actual)


def test_lemma_iv1_sorted_order_minimizes_misses():
    """Sorted probe order attains the compulsory-miss lower bound; random
    permutations can only do worse (Lemma IV.1)."""
    rng = np.random.default_rng(7)
    eps, c_ipp = 16, 8
    n = 20_000
    pos = np.sort(rng.integers(0, n, size=400))
    lo = np.clip(pos - eps, 0, n - 1) // c_ipp
    hi = np.clip(pos + eps, 0, n - 1) // c_ipp
    cap = 1 + int(np.ceil(2 * eps / c_ipp))
    sorted_misses = replay.replay_windows(lo, hi, cap, "lru").sum()
    for _ in range(5):
        perm = rng.permutation(len(pos))
        perm_misses = replay.replay_windows(lo[perm], hi[perm], cap, "lru").sum()
        assert perm_misses >= sorted_misses


def test_sorted_scan_capacity_compares_exact_above_float32():
    """Regression: page-count regime compares must be exact above 2^24.

    float32 rounds 2^24 + 1 down to 2^24, so a rounded compare would put a
    16777216-page buffer AT (not below) a 16777217-page Theorem III.1
    premise and silently skip the thrash regime at large capacities; the
    exact int32 compare path must keep the one-page distinction.
    """
    r, n = float(2**25), float(2**24)
    cov = jnp.ones((8,), jnp.float32)           # unused under recency
    caps = np.array([2**24, 2**24 + 1], np.int64)
    min_caps = np.full(2, 2**24 + 1, np.int64)
    for policy in ("lru", "fifo", "lfu"):
        h = np.asarray(cm.sorted_scan_hit_rate_grid(
            policy, jnp.broadcast_to(cov, (2, 8)),
            jnp.full((2,), r), jnp.full((2,), n), jnp.zeros((2,)),
            jnp.asarray(caps), jnp.asarray(min_caps)))
        assert h[0] == 0.0, (policy, h)         # one page short: thrash
        assert h[1] == pytest.approx(0.5), (policy, h)   # at the premise
