"""Optional-hypothesis shim.

``hypothesis`` is a dev-only dependency (declared in pyproject's ``dev``
extra).  When it is installed, this module re-exports the real API; when it
is not, property-based tests degrade to skips while every plain test in the
same module keeps running — the suite must never ERROR at collection over a
missing dev extra.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any ``st.<name>(...)`` call made at decoration time."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            # Replace the test with a zero-fixture stub (pytest ignores
            # *args/**kwargs when collecting fixture names) that skips.
            def stub(*a, **k):
                pytest.skip("hypothesis not installed (pip install .[dev])")

            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub

        return deco
