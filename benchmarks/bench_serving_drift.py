"""Serving-loop drift benchmark: adaptive vs static vs oracle retuning.

A piecewise-drifting synthetic trace (hot-set moves, range widths widen,
op mix shifts — see the segment table below) streams through four arms:

* ``static``      — tuned once on the warmup prefix, never touched again;
* ``adaptive``    — :class:`ServingSession` with the rebuild-cost gate ON:
                    retunes from the live sketch on drift, switches only
                    when predicted steady-state savings over the horizon
                    repay the modeled rebuild I/O;
* ``every_drift`` — the same loop with the gate OFF: every drift trigger
                    redeploys the retuned best (the rebuild-happy baseline);
* ``oracle``      — retuned offline on each segment's full workload at the
                    (unknowable in production) segment boundaries.

Accounting charges each arm the model-predicted I/O of its ACTIVE
configuration on each span of the stream it was active for, plus the
modeled rebuild I/O of every switch.  Two gates hold (asserted, CI fails
otherwise): the adaptive arm's total I/O is >= 1.2x lower than static, and
it issues STRICTLY fewer rebuilds than every_drift.  Results land in
``benchmarks/results/serving_drift.json``.

Run directly with ``--smoke`` for CI-sized inputs:

    python -m benchmarks.bench_serving_drift --smoke
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time

import numpy as np

from benchmarks.common import GEOM, dataset, emit
from repro.core.session import System
from repro.core.workload import Workload
from repro.serving import (ServingConfig, ServingSession,
                           synthetic_drifting_trace)
from repro.serving.trace import compile_events, iter_batches
from repro.tuning.session import PGMBuilder, TuningSession

RESULTS = pathlib.Path(__file__).parent / "results"

BUFFER_KB = 512
EPS_GRID = (8, 16, 32, 64, 128, 256, 512)


def _segments(scale: int):
    """The drift script.  Segment 2 is a short 'flash' — a hot-set blip
    that reverts: it moves enough probability mass to trigger TV drift,
    but the optimal knob barely moves, so the rebuild gate should refuse
    it while the gate-off arm rebuilds; segments 4-5 are genuine regime
    changes the adaptive arm must follow."""
    return [
        # 0: warmup — point-heavy, tight hot set, narrow ranges
        {"events": 8 * scale, "mix": (0.8, 0.2, 0.0), "hot_center": 0.2,
         "hot_width": 0.05, "hot_frac": 0.95, "range_width": 16},
        # 1: same regime continues (served steady state)
        {"events": 4 * scale, "mix": (0.8, 0.2, 0.0), "hot_center": 0.2,
         "hot_width": 0.05, "hot_frac": 0.95, "range_width": 16},
        # 2: FLASH — hot set blips elsewhere, everything else unchanged
        {"events": 2 * scale, "mix": (0.8, 0.2, 0.0), "hot_center": 0.6,
         "hot_width": 0.05, "hot_frac": 0.95, "range_width": 16},
        # 3: blip reverts
        {"events": 3 * scale, "mix": (0.8, 0.2, 0.0), "hot_center": 0.2,
         "hot_width": 0.05, "hot_frac": 0.95, "range_width": 16},
        # 4: REGIME CHANGE — range-heavy, wide scans, broad warm set
        {"events": 8 * scale, "mix": (0.1, 0.8, 0.1), "hot_center": 0.75,
         "hot_width": 0.4, "hot_frac": 0.9, "range_width": 2048},
        # 5: second regime — sorted sweeps join in
        {"events": 6 * scale, "mix": (0.2, 0.4, 0.4), "hot_center": 0.5,
         "hot_width": 0.6, "hot_frac": 0.9, "range_width": 1024,
         "sorted_run": 64},
    ]


def _price(tuning: TuningSession, builder, pt, size, capacity,
           wl: Workload) -> float:
    """Model-predicted I/O/query of ONE (knob, capacity) on ``wl``."""
    cand = builder.candidate(pt, size)
    profs = tuning.cost.grid_profiles([cand], wl)
    h, _ = tuning.cost.solve_profiles(profs, np.asarray([capacity]))
    return float((1.0 - h[0]) * profs.dacs[0])


def _rebuild_io(system: System, n: int, size_bytes: float,
                capacity: int, distinct: float) -> float:
    geom = system.geom
    return float(geom.num_pages(n) + np.ceil(size_bytes / geom.page_bytes)
                 + min(float(capacity), distinct))


def _spanify(configs, batch_wls):
    """Group consecutive batches under the same active config."""
    spans = []
    for cfg, wl in zip(configs, batch_wls):
        if spans and spans[-1][0] == cfg:
            spans[-1][1].append(wl)
        else:
            spans.append((cfg, [wl]))
    return spans


def _run_serving_arm(keys, system, cfg: ServingConfig, warmup,
                     stream_batches):
    tuning = TuningSession(system)
    srv = ServingSession(tuning, PGMBuilder(keys), keys, config=cfg,
                         overrides={"eps": EPS_GRID})
    srv.start(warmup)
    configs, batch_wls, rebuild_cost = [], [], 0.0
    for batch in stream_batches:
        wl = compile_events(batch, keys)
        report = srv.ingest(wl, ts=batch[-1].ts)
        if report.decision is not None and report.decision.switched:
            rebuild_cost += report.decision.rebuild_io
        configs.append(({"eps": srv.current.best_knob},
                        srv.current.capacity_pages))
        batch_wls.append(wl)
    return srv, _spanify(configs, batch_wls), rebuild_cost


def run(smoke: bool = False, seed: int = 0) -> dict:
    scale = 384 if smoke else 2048
    n = 50_000 if smoke else 400_000
    keys = dataset("books", n)
    system = System(GEOM, memory_budget_bytes=BUFFER_KB << 10, policy="lru")
    tuning = TuningSession(system)
    builder = PGMBuilder(keys)
    size_of = lambda pt: float(builder.size_model()(**pt))  # noqa: E731

    segs = _segments(scale)
    events = synthetic_drifting_trace(keys, segs, seed=seed)
    warmup_n = segs[0]["events"]
    warmup, stream = events[:warmup_n], events[warmup_n:]
    scfg = ServingConfig(batch_size=scale, window_chunks=4,
                         drift_threshold=0.12, hysteresis=0.04,
                         cooldown_batches=1,
                         horizon_queries=4_000 if smoke else 30_000)
    batches = list(iter_batches(stream, scfg.batch_size))

    def charge(spans):
        total = 0.0
        for (pt, cap), wls in spans:
            wl = wls[0] if len(wls) == 1 else Workload.concat(*wls)
            total += wl.n_queries * _price(tuning, builder, pt,
                                           size_of(pt), cap, wl)
        return total

    # ---- adaptive + every_drift (same stream, gate on/off) ---------------
    t0 = time.perf_counter()
    srv_a, spans_a, rb_a = _run_serving_arm(keys, system, scfg, warmup,
                                            batches)
    adaptive_seconds = time.perf_counter() - t0
    srv_e, spans_e, rb_e = _run_serving_arm(
        keys, system, dataclasses.replace(scfg, rebuild_gate=False),
        warmup, batches)

    # ---- static: the adaptive arm's initial config, frozen ---------------
    static_spans = [(spans_a[0][0], [wl for _, wls in spans_a
                                    for wl in wls])]

    # ---- oracle: offline retune on each segment's true workload ----------
    seg_groups, i = [], 0
    for seg in segs[1:]:
        k = int(np.ceil(seg["events"] / scfg.batch_size))
        seg_groups.append(batches[i:i + k])
        i += k
    oracle_spans, oracle_rb, oracle_rebuilds, prev = [], 0.0, 0, None
    for group in seg_groups:
        if not group:
            continue
        seg_wls = [compile_events(b, keys) for b in group]
        res = tuning.tune(builder, Workload.concat(*seg_wls),
                          overrides={"eps": EPS_GRID})
        cfg = ({"eps": res.best_knob}, res.capacity_pages)
        if prev is not None and cfg != prev:
            est = res.estimates[res.best_knob]
            oracle_rb += _rebuild_io(system, n, size_of(cfg[0]),
                                     res.capacity_pages, est.distinct_pages)
            oracle_rebuilds += 1
        prev = cfg
        oracle_spans.append((cfg, seg_wls))

    total_q = sum(wl.n_queries for _, wls in spans_a for wl in wls)
    arms = {}
    for name, spans, rb, rebuilds, extra in [
            ("static", static_spans, 0.0, 0, {}),
            ("adaptive", spans_a, rb_a, srv_a.stats.rebuilds,
             {"stats": dataclasses.asdict(srv_a.stats),
              "loop_seconds": adaptive_seconds}),
            ("every_drift", spans_e, rb_e, srv_e.stats.rebuilds,
             {"stats": dataclasses.asdict(srv_e.stats)}),
            ("oracle", oracle_spans, oracle_rb, oracle_rebuilds, {})]:
        serve_io = charge(spans)
        arms[name] = {"serve_io": serve_io, "rebuild_io": rb,
                      "total_io": serve_io + rb,
                      "io_per_query": (serve_io + rb) / total_q,
                      "rebuilds": rebuilds, **extra}
        emit(f"serving_drift/{name}", 1e6 * arms[name]["io_per_query"],
             f"total_io={arms[name]['total_io']:.0f} rebuilds={rebuilds}")

    ratio = arms["static"]["total_io"] / arms["adaptive"]["total_io"]
    record = {
        "n": n, "queries": total_q, "eps_grid": list(EPS_GRID),
        "buffer_kb": BUFFER_KB, "smoke": smoke, "segments": segs,
        "arms": arms,
        "static_over_adaptive_io": ratio,
        "gates": {
            "adaptive_1p2x_vs_static": ratio >= 1.2,
            "fewer_rebuilds_than_every_drift":
                arms["adaptive"]["rebuilds"]
                < arms["every_drift"]["rebuilds"],
        },
    }
    RESULTS.mkdir(exist_ok=True)
    out = RESULTS / "serving_drift.json"
    out.write_text(json.dumps(record, indent=2, default=float))
    emit("serving_drift/ratio", 0.0,
         f"static/adaptive={ratio:.2f}x rebuilds="
         f"{arms['adaptive']['rebuilds']}<{arms['every_drift']['rebuilds']}"
         f" -> {out}")
    assert record["gates"]["adaptive_1p2x_vs_static"], \
        f"adaptive only {ratio:.2f}x better than static (< 1.2x)"
    assert record["gates"]["fewer_rebuilds_than_every_drift"], \
        (f"adaptive issued {arms['adaptive']['rebuilds']} rebuilds, "
         f"every_drift {arms['every_drift']['rebuilds']}")
    return record


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized inputs (~seconds)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    run(smoke=args.smoke, seed=args.seed)


if __name__ == "__main__":
    main()
