"""Shared benchmark scaffolding.

Paper experiments use 200M-key datasets on an NVMe server; this container is
1-CPU, so defaults scale down ~100x while preserving the regime ratios
(eps/C_ipp, buffer/data, queries/pages).  Every benchmark accepts
``scale(n)`` so results can be grown toward paper scale on bigger hosts.

Output convention: ``emit(name, us_per_call, derived)`` CSV lines.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Tuple

import numpy as np

from repro.core import cam
from repro.data.datasets import make_dataset
from repro.data.workloads import WorkloadSpec, point_workload, range_workload
from repro.index.disk_layout import PageLayout
from repro.index.pgm import build_pgm

DEFAULT_N = 2_000_000
DEFAULT_Q = 200_000
GEOM = cam.CamGeometry(c_ipp=256, page_bytes=4096)
LAYOUT = PageLayout(c_ipp=256, page_bytes=4096)

_DATA_CACHE: Dict[Tuple[str, int, int], np.ndarray] = {}
_PGM_CACHE: Dict[Tuple[str, int, int, int], object] = {}


def dataset(name: str, n: int = DEFAULT_N, seed: int = 1) -> np.ndarray:
    key = (name, n, seed)
    if key not in _DATA_CACHE:
        _DATA_CACHE[key] = make_dataset(name, n, seed)
    return _DATA_CACHE[key]


def pgm_for(name: str, eps: int, n: int = DEFAULT_N, seed: int = 1):
    key = (name, eps, n, seed)
    if key not in _PGM_CACHE:
        _PGM_CACHE[key] = build_pgm(dataset(name, n, seed), eps)
    return _PGM_CACHE[key]


def point_queries(name: str, wl: str, n: int = DEFAULT_N,
                  n_queries: int = DEFAULT_Q, seed: int = 3):
    keys = dataset(name, n)
    return point_workload(keys, n_queries, WorkloadSpec(wl, seed=seed))


def range_queries(name: str, wl: str, n: int = DEFAULT_N,
                  n_queries: int = DEFAULT_Q // 4, seed: int = 3):
    keys = dataset(name, n)
    return range_workload(keys, n_queries, WorkloadSpec(wl, seed=seed),
                          max_len=2048)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
