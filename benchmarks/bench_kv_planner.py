"""Beyond-paper: CAM-guided KV-pool planner accuracy — structural closed-form
vs IRM (Che) vs PagedKVPool replay across HBM budgets (the Eq. 15 analogue
on the serving plane; see DESIGN.md §4 and EXPERIMENTS.md §Findings 2)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.serve.kv_cache import BlockTrace, PagedKVPool
from repro.serve.planner import RequestMix, block_popularity, structural_hit_rate


def run():
    mix = RequestMix(n_requests=24, shared_prefix=1024, mean_context=2048,
                     decode_steps=16, kv_bytes_per_token=1024)
    bt = 64
    probs, refs = block_popularity(mix, bt)
    n_distinct = probs.shape[0]
    rng = np.random.default_rng(0)
    schedule = [(int(r), mix.shared_prefix, mix.mean_context)
                for _ in range(mix.decode_steps)
                for r in rng.permutation(mix.n_requests)]
    trace = BlockTrace(bt).decode_trace(schedule)
    import jax.numpy as jnp
    from repro.core import cache_models

    for frac in (0.2, 0.4, 0.6, 0.9, 1.2):
        pool_blocks = max(1, int(n_distinct * frac))
        pool = PagedKVPool(pool_blocks, bt, 1024 * bt)
        for ref in trace:
            pool.reference(ref)
        h_struct = (structural_hit_rate(mix, bt, pool_blocks)
                    if pool_blocks < n_distinct else pool.hit_rate)
        h_irm = float(cache_models.hit_rate(
            "lru", min(pool_blocks, n_distinct - 1),
            jnp.asarray(probs, jnp.float32),
            total_requests=len(trace)))
        emit(f"kv_planner/pool{frac:.1f}N", 0.0,
             f"replay={pool.hit_rate:.3f};structural={h_struct:.3f}"
             f";irm_che={h_irm:.3f}"
             f";struct_err={abs(h_struct - pool.hit_rate):.3f}"
             f";irm_err={abs(h_irm - pool.hit_rate):.3f}")


if __name__ == "__main__":
    run()
