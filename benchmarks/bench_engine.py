"""Engine executor benchmark: the fused device solve vs the host reference.

Builds a >= 10^4-cell :class:`repro.engine.PriceTable` (a knob-grid profile
batch x a dense per-knob capacity curve, over a mixed point+sorted workload
so the full policy-fixed-point + sorted/mixed composition runs) and prices
the SAME table through both executors:

* ``host``   — ``CostSession.solve_profiles`` (the golden reference);
* ``device`` — the fused ``kernels/price_grid.py`` pallas kernel: bisection,
  sorted/mixed composition and objective argmin in ONE launch.

On a real TPU backend the fused executor must be >= 2x faster warm than the
host path (that is the point of fusing the pipeline into one HBM pass over
the histograms).  Under interpret mode (CPU CI) kernel timings are
meaningless, so the gate degrades to structure-only: float32 equivalence of
every cell's hit rate, identical distinct-page counts, and winner agreement
— asserted on both backends.  Results land in
``benchmarks/results/engine_fused.json``.

Run directly with ``--smoke`` for CI-sized inputs:

    python -m benchmarks.bench_engine --smoke
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks.common import GEOM, dataset, emit
from repro.core.session import CostSession, GridCandidate, System
from repro.core.workload import Workload
from repro.data.workloads import WorkloadSpec, point_workload, range_workload
from repro.engine import PriceTable, PricingEngine

RESULTS = pathlib.Path(__file__).parent / "results"

BUDGET = 8 << 20
N_KNOBS = 16
CAPS_PER_KNOB = 640          # 16 x 640 = 10_240 cells, every run
POLICY = "lfu"               # the heaviest kernel branch (sorts + coverage)
REPEATS = 3
GATE_SPEEDUP = 2.0


def _table(sess: CostSession, keys: np.ndarray, nq: int,
           seed: int) -> PriceTable:
    n = len(keys)
    qk, qpos = point_workload(keys, nq, WorkloadSpec("w4", seed=seed))
    _, _, rlop, rhip = range_workload(keys, max(nq // 4, 64),
                                      WorkloadSpec("w1", seed=seed + 1), 64)
    wl = Workload.mixed(Workload.point(qpos, n=n),
                        Workload.sorted_stream(np.sort(rlop), np.sort(rhip),
                                               n=n))
    eps_grid = np.unique(np.geomspace(4, 512, N_KNOBS).astype(int))
    cands = [GridCandidate(int(e), 65_536.0, eps=int(e)) for e in eps_grid]
    prof = sess.grid_profiles(cands, wl)
    cells = []
    for i, kn in enumerate(prof.knobs):
        caps = np.unique(np.geomspace(
            1, max(int(prof.caps[i]), 2), CAPS_PER_KNOB).astype(np.int64))
        caps = np.concatenate([caps, np.arange(1, CAPS_PER_KNOB
                                               - caps.shape[0] + 1)
                               + caps.max()])       # exactly CAPS_PER_KNOB
        cells.append((kn, i, caps[:CAPS_PER_KNOB]))
    return PriceTable.from_cells(prof, cells)


def _time(fn, repeats: int = REPEATS) -> float:
    fn()                                            # warm (jit compile)
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(smoke: bool = False, seed: int = 0) -> dict:
    import jax

    n, nq = (40_000, 8_000) if smoke else (200_000, 40_000)
    keys = dataset("books", n)
    sess = CostSession(System(GEOM, memory_budget_bytes=BUDGET,
                              policy=POLICY))
    tab = _table(sess, keys, nq, seed)
    assert len(tab) >= 10_000, len(tab)
    eng = PricingEngine(sess)

    sol_h = eng.price(tab, executor="host")
    sol_d = eng.price(tab, executor="device")
    dh = float(np.max(np.abs(sol_h.hit_rates - sol_d.hit_rates)))
    equivalent = dh < 2e-6 and np.array_equal(sol_h.distinct, sol_d.distinct)
    winner_ok = bool(np.isclose(sol_h.objective[sol_d.best_cell],
                                sol_h.objective[sol_h.best_cell],
                                rtol=1e-5, atol=1e-12))

    host_s = _time(lambda: eng.price(tab, executor="host"))
    device_s = _time(lambda: eng.price(tab, executor="device"))
    speedup = host_s / device_s
    on_tpu = jax.default_backend() == "tpu"

    record = {
        "cells": len(tab), "rows": int(len(tab.profiles.knobs)),
        "caps_per_knob": CAPS_PER_KNOB, "n": n, "queries": nq,
        "policy": POLICY, "backend": jax.default_backend(),
        "fused_timed": on_tpu,          # interpret timings are meaningless
        "host_seconds_warm": host_s, "device_seconds_warm": device_s,
        "device_over_host_speedup": speedup,
        "max_abs_hit_rate_diff": dh, "smoke": smoke,
        "gates": {
            "float32_equivalent": bool(equivalent),
            "winner_agrees": winner_ok,
            f"fused_{GATE_SPEEDUP}x_warm": (bool(speedup >= GATE_SPEEDUP)
                                            if on_tpu else None),
        },
    }
    RESULTS.mkdir(exist_ok=True)
    out = RESULTS / "engine_fused.json"
    out.write_text(json.dumps(record, indent=2, default=float))
    emit("engine/host", 1e6 * host_s, f"{len(tab)} cells warm")
    emit("engine/device", 1e6 * device_s,
         f"speedup={speedup:.2f}x dh={dh:.1e} "
         f"({'timed' if on_tpu else 'interpret: structure-only'}) -> {out}")

    assert equivalent, f"executors diverge: max |dh| = {dh}"
    assert winner_ok, "fused argmin disagrees with the host winner"
    if on_tpu:
        assert speedup >= GATE_SPEEDUP, (
            f"fused executor only {speedup:.2f}x over host "
            f"(< {GATE_SPEEDUP}x) on {len(tab)} cells")
    return record


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized inputs (~seconds)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    run(smoke=args.smoke, seed=args.seed)


if __name__ == "__main__":
    main()
