"""Fig. 8: CAM-estimated vs actual I/O for RMI across branch factors —
the sharp right-edge rise when the index squeezes out the buffer."""
from __future__ import annotations

import numpy as np

from benchmarks.common import DEFAULT_N, GEOM, dataset, emit
from repro.core.qerror import q_error
from repro.core.replay import replay_windows
from repro.data.workloads import WorkloadSpec, point_workload
from repro.index.rmi import build_rmi
from repro.tuning.rmi_tuner import estimate_rmi_io

BRANCH_GRID = (2**8, 2**10, 2**12, 2**14, 2**16)


def run(n=DEFAULT_N, n_queries=100_000, budgets_mb=(2, 4)):
    keys = dataset("books", n)
    qk, qpos = point_workload(keys, n_queries, WorkloadSpec("w4", seed=3))
    for policy in ("lru", "fifo"):
        for mem_mb in budgets_mb:
            m_budget = mem_mb << 20
            curve_est, curve_act = {}, {}
            for branch in BRANCH_GRID:
                idx = build_rmi(keys, branch)
                if idx.size_bytes >= m_budget - GEOM.page_bytes:
                    continue
                est = estimate_rmi_io(idx, qpos, qk, GEOM, m_budget,
                                      policy=policy)
                cap = max(1, (m_budget - idx.size_bytes) // GEOM.page_bytes)
                wlo, whi, _ = idx.window(qk)
                misses = replay_windows(wlo // GEOM.c_ipp, whi // GEOM.c_ipp,
                                        cap, policy)
                curve_est[branch] = est.io_per_query
                curve_act[branch] = float(misses.mean())
            if not curve_est:
                continue
            best_est = min(curve_est, key=curve_est.get)
            best_act = min(curve_act, key=curve_act.get)
            qerrs = [float(q_error(curve_est[b], curve_act[b]))
                     for b in curve_est]
            emit(f"fig8/{policy}/{mem_mb}MB", 0.0,
                 f"branch_star_cam={best_est};branch_star_actual={best_act}"
                 f";curve_qerr={np.mean(qerrs):.3f}")


if __name__ == "__main__":
    run()
