"""Fig. 8: CAM-estimated vs actual I/O for RMI across branch factors —
the sharp right-edge rise when the index squeezes out the buffer.

The branch grid prices through ONE ``TuningSession.tune`` call per
(policy, budget): the prebuilt candidates profile through the batched
mixed-eps kernel (one grouped pass for the whole grid) and all hit rates
solve in one vmapped pass."""
from __future__ import annotations

import numpy as np

from benchmarks.common import DEFAULT_N, GEOM, dataset, emit
from repro.core.qerror import q_error
from repro.core.replay import replay_windows
from repro.core.session import System
from repro.core.workload import Workload
from repro.data.workloads import WorkloadSpec, point_workload
from repro.index.adapters import RMIAdapter
from repro.index.rmi import build_rmi
from repro.tuning.session import RMIBuilder, TuningSession

BRANCH_GRID = (2**8, 2**10, 2**12, 2**14, 2**16)


def run(n=DEFAULT_N, n_queries=100_000, budgets_mb=(2, 4)):
    keys = dataset("books", n)
    qk, qpos = point_workload(keys, n_queries, WorkloadSpec("w4", seed=3))
    wl = Workload.point(qpos, n=n, query_keys=qk)
    builder = RMIBuilder(keys)
    builder.built = {b: RMIAdapter(build_rmi(keys, b)) for b in BRANCH_GRID}
    for policy in ("lru", "fifo"):
        for mem_mb in budgets_mb:
            m_budget = mem_mb << 20
            session = TuningSession(System(GEOM, m_budget, policy))
            try:
                res = session.tune(builder, wl,
                                   overrides={"branch": BRANCH_GRID})
            except ValueError:
                continue  # budget below every candidate's footprint
            curve_est = {b: est.io_per_query
                         for b, est in res.estimates.items()}
            curve_act = {}
            for b in curve_est:
                idx = builder.built[b].index
                cap = max(1, (m_budget - idx.size_bytes) // GEOM.page_bytes)
                wlo, whi, _ = idx.window(qk)
                misses = replay_windows(wlo // GEOM.c_ipp, whi // GEOM.c_ipp,
                                        cap, policy)
                curve_act[b] = float(misses.mean())
            best_est = res.best_knob
            best_act = min(curve_act, key=curve_act.get)
            qerrs = [float(q_error(curve_est[b], curve_act[b]))
                     for b in curve_est]
            emit(f"fig8/{policy}/{mem_mb}MB",
                 res.tuning_seconds * 1e6 / max(len(curve_est), 1),
                 f"branch_star_cam={best_est};branch_star_actual={best_act}"
                 f";curve_qerr={np.mean(qerrs):.3f}")


if __name__ == "__main__":
    run()
