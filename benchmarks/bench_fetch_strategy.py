"""Fig. 5 + Lemmas III.2/III.3: all-at-once vs one-by-one fetching.

Closed-form E[DAC] vs measured page counts from a built index, plus modeled
device time under the parallel I/O model: one-by-one reads fewer pages but
issues DEPENDENT random I/Os that can't use SSD concurrency — all-at-once
wins at thread count >= ~16 (the paper's crossover)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import DEFAULT_N, GEOM, LAYOUT, dataset, emit, pgm_for
from repro.core import dac
from repro.data.workloads import WorkloadSpec, point_workload
from repro.index.disk_layout import fetch_all_at_once, fetch_one_by_one_counts


def run(n=DEFAULT_N, n_queries=100_000):
    keys = dataset("books", n)
    qk, qpos = point_workload(keys, n_queries, WorkloadSpec("w1", seed=5))
    for eps in (64, 256, 1024, 4096):
        idx = pgm_for("books", eps, n)
        wlo, whi = idx.window(qk)
        plo, phi = fetch_all_at_once(wlo, whi, LAYOUT)
        pages_aao = (phi - plo + 1).astype(np.float64)
        pages_obo = fetch_one_by_one_counts(wlo, qpos, LAYOUT).astype(np.float64)
        closed_aao = float(dac.expected_dac_all_at_once(eps, GEOM.c_ipp))
        closed_obo = float(dac.expected_dac_one_by_one(eps, GEOM.c_ipp))
        # device-time model: latency per dependent read ~80us; coalesced read
        # setup 80us + 16us/page transfer; threads hide independent I/Os.
        for threads in (1, 16, 64):
            t_aao = (80.0 + 16.0 * pages_aao.mean()) / min(threads, 64)
            t_obo = 80.0 * pages_obo.mean() / min(threads, 4)  # dependent chain
            emit(f"fig5/eps{eps}/threads{threads}", 0.0,
                 f"aao_pages={pages_aao.mean():.3f}(closed={closed_aao:.3f})"
                 f";obo_pages={pages_obo.mean():.3f}(closed={closed_obo:.3f})"
                 f";speedup_aao={t_obo / t_aao:.2f}x")


if __name__ == "__main__":
    run()
