"""Sharding benchmark: CAM-solved boundaries vs the even key split.

A Zipf-flavored hotspot concentrates ~92% of the traffic in a key slab
WIDER than any single shard's maximal fleet-budget share, dropped inside
the even split's first shard.  The even key split therefore cannot cache
the hot set no matter how the budget simplex tilts toward the hot shard —
while boundary search can divide the slab across all nodes so the union
of their buffers covers it.  Both arms run the SAME joint solver (per-
shard knob and fleet budget split are optimized for each); only the
boundary candidate set differs:

* ``even``   — the even key split only (knob + budget still solved);
* ``solved`` — the full candidate grid (even + traffic quantiles +
  blends), one grouped profile pass + one solve pass for the whole
  (boundary × shard × knob × share) table.

Gate (asserted per policy, CI fails otherwise): solved boundaries beat
the even split by >= 1.15x fleet I/O under lru, fifo AND lfu.  Results
land in ``benchmarks/results/sharding.json``.

Run directly with ``--smoke`` for CI-sized inputs:

    python -m benchmarks.bench_sharding --smoke
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks.common import GEOM, dataset, emit
from repro.core.session import System
from repro.core.workload import Workload
from repro.sharding import ShardingSession, even_boundaries
from repro.tuning.session import PGMBuilder

RESULTS = pathlib.Path(__file__).parent / "results"

N_SHARDS = 4
BUDGET_GRID = 8
EPS_GRID = (8, 32, 128)
POLICIES = ("lru", "fifo", "lfu")
GATE_RATIO = 1.15


def _hotspot_workload(n: int, nq: int, slab_pages: int,
                      hot_frac: float = 0.92, seed: int = 0) -> Workload:
    """~uniform hot slab of ``slab_pages`` pages + a uniform cold tail.

    The slab is kept flat on purpose: within-slab skew would let LFU/LRU
    pin the hottest pages under ANY boundaries, hiding the coverage
    effect the benchmark isolates.
    """
    rng = np.random.default_rng(seed)
    slab = slab_pages * GEOM.c_ipp
    hot = rng.integers(0, slab, int(nq * hot_frac))
    cold = rng.integers(0, n, nq - hot.shape[0])
    pos = np.concatenate([hot, cold])
    rng.shuffle(pos)
    return Workload.point(pos, n=n)


def run(smoke: bool = False, seed: int = 0) -> dict:
    # the hot slab must overflow the max single-shard share:
    # fleet = N_SHARDS * node budget; max share = 5/8 of it
    if smoke:
        n, nq, node_kb, slab_pages = 40_000, 20_000, 32, 30
    else:
        n, nq, node_kb, slab_pages = 200_000, 100_000, 160, 150
    keys = dataset("books", n)
    wl = _hotspot_workload(n, nq, slab_pages, seed=seed)
    even = even_boundaries(n, N_SHARDS)

    policies, gates = {}, {}
    for policy in POLICIES:
        node = System(GEOM, memory_budget_bytes=node_kb << 10, policy=policy)
        sess = ShardingSession(node, PGMBuilder(keys), N_SHARDS,
                               grid=BUDGET_GRID,
                               overrides={"eps": EPS_GRID})
        t0 = time.perf_counter()
        solved = sess.solve(wl)
        solve_seconds = time.perf_counter() - t0
        even_plan = sess.solve(wl, [even])
        ratio = even_plan.io_per_query / solved.io_per_query
        policies[policy] = {
            "solved_io_per_query": solved.io_per_query,
            "even_io_per_query": even_plan.io_per_query,
            "even_over_solved": ratio,
            "boundaries": list(solved.boundaries),
            "fractions": list(solved.fractions),
            "eps": [p.knob for p in solved.shards],
            "shard_masses": list(solved.shard_masses),
            "cells_solved": solved.cells_solved,
            "boundaries_searched": len(solved.boundaries_searched),
            "solve_seconds": solve_seconds,
        }
        gates[policy] = ratio >= GATE_RATIO
        emit(f"sharding/{policy}", 1e6 * solved.io_per_query,
             f"even/solved={ratio:.2f}x boundaries={solved.boundaries} "
             f"cells={solved.cells_solved}")

    record = {
        "n": n, "queries": nq, "n_shards": N_SHARDS,
        "budget_grid": BUDGET_GRID, "node_budget_kb": node_kb,
        "fleet_budget_kb": node_kb * N_SHARDS,
        "hot_slab_pages": slab_pages, "eps_grid": list(EPS_GRID),
        "smoke": smoke, "policies": policies,
        "gates": {f"solved_{GATE_RATIO}x_vs_even_{p}": g
                  for p, g in gates.items()},
    }
    RESULTS.mkdir(exist_ok=True)
    out = RESULTS / "sharding.json"
    out.write_text(json.dumps(record, indent=2, default=float))
    worst = min(policies[p]["even_over_solved"] for p in POLICIES)
    emit("sharding/ratio", 0.0,
         f"worst even/solved={worst:.2f}x over {POLICIES} -> {out}")
    for policy in POLICIES:
        assert gates[policy], (
            f"solved boundaries only "
            f"{policies[policy]['even_over_solved']:.2f}x better than the "
            f"even split under {policy} (< {GATE_RATIO}x)")
    return record


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized inputs (~seconds)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    run(smoke=args.smoke, seed=args.seed)


if __name__ == "__main__":
    main()
