"""Table V: CAM vs Replay vs LPM on range workloads — Q-error + time."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (DEFAULT_N, DEFAULT_Q, GEOM, Timer, dataset,
                               emit, pgm_for, range_queries)
from repro.core import cam, lpm
from repro.core.qerror import q_error
from repro.core.replay import replay_windows

EPS_SWEEP = (16, 64, 256)
BUFFER_MB = 8


def _actual_windows(idx, lo_keys, hi_keys, n):
    """Replay windows per the paper's range execution: one all-at-once fetch
    from window(lo).start to window(hi).end (predictions, not true ranks)."""
    lo_pred = idx.predict(lo_keys)
    hi_pred = idx.predict(hi_keys)
    wlo = np.clip(lo_pred - idx.eps, 0, n - 1)
    whi = np.clip(np.maximum(hi_pred + idx.eps, wlo), 0, n - 1)
    return wlo, whi


def run(datasets=("books", "osm"), workloads=("w1", "w2", "w4", "w6"),
        n=DEFAULT_N, n_queries=DEFAULT_Q // 4, policy="lru"):
    for ds in datasets:
        for wl in workloads:
            lo_k, hi_k, lo_pos, hi_pos = range_queries(ds, wl, n, n_queries)
            results = {}
            truth = {}
            for eps in EPS_SWEEP:
                idx = pgm_for(ds, eps, n)
                m_budget = BUFFER_MB << 20
                cap = max(1, (m_budget - idx.size_bytes) // GEOM.page_bytes)
                wlo, whi = _actual_windows(idx, lo_k, hi_k, n)
                plo, phi = wlo // GEOM.c_ipp, whi // GEOM.c_ipp
                with Timer() as t_truth:
                    misses = replay_windows(plo, phi, cap, policy)
                truth[eps] = (misses.mean(), t_truth.seconds)

                for rate in (0.1, 1.0):
                    cam.estimate_range_io(lo_pos, hi_pos, eps, n, GEOM,
                                          m_budget, idx.size_bytes,
                                          policy=policy, sample_rate=rate)
                    with Timer() as t:
                        est = cam.estimate_range_io(
                            lo_pos, hi_pos, eps, n, GEOM, m_budget,
                            idx.size_bytes, policy=policy, sample_rate=rate)
                    results.setdefault(f"CAM-{int(rate*100)}", []).append(
                        (est.io_per_query, t.seconds))
                    k = max(1, int(n_queries * rate))
                    with Timer() as t:
                        m = replay_windows(plo[:k], phi[:k], cap, policy)
                    results.setdefault(f"Replay-{int(rate*100)}", []).append(
                        (m.mean(), t.seconds))
                with Timer() as t:
                    est_lpm = lpm.lpm_estimate_from_windows(plo, phi)
                results.setdefault("LPM", []).append((est_lpm, t.seconds))

            for tag, rows in results.items():
                qerrs = [float(q_error(io, truth[eps][0]))
                         for (io, _), eps in zip(rows, EPS_SWEEP)]
                total_t = sum(t for _, t in rows)
                replay_t = sum(truth[e][1] for e in EPS_SWEEP)
                emit(f"tableV/{ds}/{wl}/{tag}",
                     total_t / len(rows) * 1e6,
                     f"mean_qerr={np.mean(qerrs):.3f}"
                     f";speedup_vs_replay100={replay_t / max(total_t, 1e-9):.2f}x")


if __name__ == "__main__":
    run()
