"""Figs. 9/10: end-to-end tuner comparison — throughput of the configuration
each tuner picks under a shared memory budget, plus tuning time.

Everything tunes through ONE surface (``repro.tuning.session.TuningSession``):
CAM is the joint (knob x buffer-split) search; the cache-oblivious baselines
(multicriteria-PGM, CDFShop) are pluggable ``Tuner`` strategies that reserve
a fixed buffer fraction and profile candidates in the remainder.  Three
records land in ``benchmarks/results/tuning_e2e.json``:

* ``pgm``/``rmi`` — CAM-vs-multicriteria and CAM-vs-CDFShop replayed-QPS
  ratios per budget;
* ``radixspline_joint`` — jointly tuned (eps, radix_bits) vs eps-only tuning
  at the legacy fixed radix_bits=16 (the table competes with buffer pages);
* ``mixed_eps_kernel`` — the batched grouped kernel pricing a full RMI
  branch grid vs the per-branch mixture-histogram path (warm, same grid,
  same solve; gate: >= 3x).

    python -m benchmarks.bench_tuning_e2e [--smoke]
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import DEFAULT_N, GEOM, dataset, emit
from repro.core.session import CostSession, GridCandidate, System
from repro.core.workload import Workload
from repro.data.workloads import WorkloadSpec, point_workload
from repro.index.adapters import DEFAULT_BRANCH_GRID
from repro.sim.machine import simulate_point_queries
from repro.tuning.session import (CDFShopTuner, MulticriteriaTuner,
                                  PGMBuilder, RMIBuilder, RadixSplineBuilder,
                                  TuningSession)

OUT_PATH = os.path.join(os.path.dirname(__file__), "results",
                        "tuning_e2e.json")

RMI_GRID = (2**8, 2**10, 2**12, 2**14, 2**16)
RS_EPS_GRID = (16, 32, 64, 128, 256, 512, 1024)
RS_BITS_GRID = (8, 10, 12, 14, 16)


def _qps(builder, point, qk, m_budget, policy="lru"):
    """Replayed throughput of one tuned configuration (ground truth)."""
    adapter = builder.build(point)
    cap = max(1, (m_budget - adapter.size_bytes) // GEOM.page_bytes)
    plo, phi = adapter.probe_windows(qk, GEOM)
    _, qps, misses = simulate_point_queries(plo, phi, cap, policy)
    return qps, misses


def _mixed_eps_ab(keys, wl, budget, reps=5):
    """Warm A/B: batched grouped kernel vs per-branch mixture histograms."""
    builder = RMIBuilder(keys)
    session = CostSession(System(GEOM, budget, "lru"))
    cands = []
    for b in DEFAULT_BRANCH_GRID:
        adapter = builder.build({"branch": b})
        cands.append(GridCandidate(knob=b, size_bytes=adapter.size_bytes,
                                   index=adapter))
    out = {}
    for label, flag in (("batched", True), ("per_branch", False)):
        session.estimate_grid(cands, wl, sample_rate=0.3,
                              batch_mixed_eps=flag)      # warm-up
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            res = session.estimate_grid(cands, wl, sample_rate=0.3,
                                        batch_mixed_eps=flag)
            times.append(time.perf_counter() - t0)
        out[label] = min(times)
        out[f"{label}_best_branch"] = int(res.best_knob)
    out["speedup_warm"] = out["per_branch"] / max(out["batched"], 1e-9)
    out["n_candidates"] = len(cands)
    return out


def run(n=DEFAULT_N, n_queries=100_000,
        budgets_mb=(0.5, 0.8, 1.0, 1.5, 2, 3.5), out_path=OUT_PATH):
    keys = dataset("books", n)
    qk, qpos = point_workload(keys, n_queries, WorkloadSpec("w4", seed=3))
    wl = Workload.point(qpos, n=len(keys), query_keys=qk)

    # Builders are shared across budgets: size models fit once, candidate
    # indexes build once (the session re-prices them per budget).
    pgm_b, rmi_b, rs_b = PGMBuilder(keys), RMIBuilder(keys), \
        RadixSplineBuilder(keys)
    record = {"n": int(n), "n_queries": int(n_queries), "budgets": {}}

    for mem_mb in budgets_mb:
        m = int(mem_mb * 2**20)
        ts = TuningSession(System(GEOM, m, "lru"))
        entry = {}

        # --- PGM: CAM joint search vs multicriteria baseline
        res = ts.tune(pgm_b, wl, sample_rate=0.3)
        qps_cam, _ = _qps(pgm_b, res.best, qk, m)
        base = ts.tune(pgm_b, wl, tuner=MulticriteriaTuner())
        qps_base, _ = _qps(pgm_b, base.best, qk, m)
        entry["pgm"] = {
            "cam_eps": int(res.best_knob), "cam_qps": qps_cam,
            "multicriteria_eps": int(base.best_knob),
            "multicriteria_qps": qps_base,
            "qps_gain": qps_cam / max(qps_base, 1),
            "cam_split": res.split,
            "tuning_time_ratio": res.tuning_seconds
            / max(base.tuning_seconds, 1e-9),
        }
        emit(f"fig9/pgm/{mem_mb}MB", res.tuning_seconds * 1e6,
             f"cam_eps={res.best_knob};cam_qps={qps_cam:.0f}"
             f";base_eps={base.best_knob};base_qps={qps_base:.0f}"
             f";qps_gain={qps_cam / max(qps_base, 1):.2f}x")

        # --- RMI: CAM (batched mixed-eps grid) vs CDFShop baseline
        rres = ts.tune(rmi_b, wl, overrides={"branch": RMI_GRID},
                       sample_rate=0.3)
        qps_rmi, _ = _qps(rmi_b, rres.best, qk, m)
        cdf = ts.tune(rmi_b, wl, tuner=CDFShopTuner(),
                      overrides={"branch": RMI_GRID})
        qps_cdf, _ = _qps(rmi_b, cdf.best, qk, m)
        entry["rmi"] = {
            "cam_branch": int(rres.best_knob), "cam_qps": qps_rmi,
            "cdfshop_branch": int(cdf.best_knob), "cdfshop_qps": qps_cdf,
            "qps_gain": qps_rmi / max(qps_cdf, 1),
            "skipped_unbuilt": [int(s.knob) for s in rres.skipped],
        }
        emit(f"fig10/rmi/{mem_mb}MB", rres.tuning_seconds * 1e6,
             f"cam_branch={rres.best_knob};cam_qps={qps_rmi:.0f}"
             f";cdfshop_branch={cdf.best_knob};cdfshop_qps={qps_cdf:.0f}"
             f";qps_gain={qps_rmi / max(qps_cdf, 1):.2f}x")

        # --- RadixSpline: joint (eps, radix_bits) vs eps-only at bits=16
        try:
            joint = ts.tune(rs_b, wl, sample_rate=0.3,
                            overrides={"eps": RS_EPS_GRID,
                                       "radix_bits": RS_BITS_GRID})
            eps_only = ts.tune(rs_b, wl, sample_rate=0.3,
                               overrides={"eps": RS_EPS_GRID,
                                          "radix_bits": 16})
        except ValueError:
            record["budgets"][str(mem_mb)] = entry
            continue  # budget below the eps-only radix-table floor
        qps_joint, _ = _qps(rs_b, joint.best, qk, m)
        qps_eps_only, _ = _qps(rs_b, eps_only.best, qk, m)
        entry["radixspline_joint"] = {
            "joint_eps": int(joint.best["eps"]),
            "joint_radix_bits": int(joint.best["radix_bits"]),
            "joint_qps": qps_joint,
            "eps_only_eps": int(eps_only.best["eps"]),
            "eps_only_qps": qps_eps_only,
            "qps_gain": qps_joint / max(qps_eps_only, 1),
        }
        emit(f"fig10b/radixspline/{mem_mb}MB", joint.tuning_seconds * 1e6,
             f"joint=({joint.best['eps']},{joint.best['radix_bits']})"
             f";joint_qps={qps_joint:.0f};eps_only_qps={qps_eps_only:.0f}"
             f";qps_gain={qps_joint / max(qps_eps_only, 1):.2f}x")
        record["budgets"][str(mem_mb)] = entry

    # --- the batched mixed-eps kernel vs the per-branch path (warm)
    ab_budget = int(max(budgets_mb) * 2**20) + (2 << 20)
    record["mixed_eps_kernel"] = _mixed_eps_ab(keys, wl, ab_budget)
    emit("tuning_e2e/mixed_eps_kernel",
         record["mixed_eps_kernel"]["batched"] * 1e6,
         f"speedup_warm={record['mixed_eps_kernel']['speedup_warm']:.2f}x"
         f";candidates={record['mixed_eps_kernel']['n_candidates']}")

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    emit("tuning_e2e/json", 0.0, f"path={os.path.relpath(out_path)}")
    return record


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized inputs (~10x below the CPU default)")
    args = ap.parse_args()
    if args.smoke:
        run(n=200_000, n_queries=20_000, budgets_mb=(0.5, 1.0))
    else:
        run()
