"""Figs. 9/10: end-to-end tuner comparison — throughput of the configuration
each tuner picks under a shared memory budget, plus tuning time.

Baselines reserve a fixed fraction of M as buffer and tune the index within
the remainder (cache-oblivious); CAM tunes the split itself.
"""
from __future__ import annotations

from benchmarks.common import DEFAULT_N, GEOM, Timer, dataset, emit
from repro.core import cam
from repro.core.replay import replay_windows
from repro.data.workloads import WorkloadSpec, point_workload
from repro.index.pgm import build_pgm
from repro.index.rmi import build_rmi
from repro.sim.machine import simulate_point_queries
from repro.index.radixspline import build_radixspline
from repro.tuning.pgm_tuner import cam_tune_pgm, multicriteria_pgm_tune
from repro.tuning.rmi_tuner import cam_tune_rmi, cdfshop_tune_rmi
from repro.tuning.rs_tuner import cam_tune_radixspline

BASELINE_BUFFER_FRAC = 0.5


def _qps_pgm(keys, qk, eps, m_budget, policy="lru"):
    idx = build_pgm(keys, eps)
    cap = max(1, (m_budget - idx.size_bytes) // GEOM.page_bytes)
    wlo, whi = idx.window(qk)
    _, qps, misses = simulate_point_queries(
        wlo // GEOM.c_ipp, whi // GEOM.c_ipp, cap, policy)
    return qps, misses


def run(n=DEFAULT_N, n_queries=100_000, budgets_mb=(0.5, 0.8, 1.0, 1.5, 2, 3.5)):
    keys = dataset("books", n)
    qk, qpos = point_workload(keys, n_queries, WorkloadSpec("w4", seed=3))

    for mem_mb in budgets_mb:
        m_budget = int(mem_mb * 2**20)
        # --- PGM
        res = cam_tune_pgm(keys, qpos, m_budget, GEOM, "lru", sample_rate=0.3)
        qps_cam, _ = _qps_pgm(keys, qk, res.best_eps, m_budget)
        base_eps, base_t = multicriteria_pgm_tune(
            keys, index_space_budget=(1 - BASELINE_BUFFER_FRAC) * m_budget)
        qps_base, _ = _qps_pgm(keys, qk, base_eps, m_budget)
        emit(f"fig9/pgm/{mem_mb}MB", res.tuning_seconds * 1e6,
             f"cam_eps={res.best_eps};cam_qps={qps_cam:.0f}"
             f";base_eps={base_eps};base_qps={qps_base:.0f}"
             f";qps_gain={qps_cam / max(qps_base, 1):.2f}x"
             f";tuning_time_ratio={res.tuning_seconds / max(base_t, 1e-9):.2f}")

        # --- RMI
        grid = (2**8, 2**10, 2**12, 2**14, 2**16)
        rres = cam_tune_rmi(keys, qpos, qk, m_budget, GEOM, "lru",
                            branch_grid=grid, sample_rate=0.3)
        idx = rres.indexes[rres.best_branch]
        cap = max(1, (m_budget - idx.size_bytes) // GEOM.page_bytes)
        wlo, whi, _ = idx.window(qk)
        _, qps_cam_rmi, _ = simulate_point_queries(
            wlo // GEOM.c_ipp, whi // GEOM.c_ipp, cap, "lru")
        cb, ct, built = cdfshop_tune_rmi(
            keys, index_space_budget=(1 - BASELINE_BUFFER_FRAC) * m_budget,
            branch_grid=grid)
        idx_b = built[cb]
        cap_b = max(1, (m_budget - idx_b.size_bytes) // GEOM.page_bytes)
        wlo, whi, _ = idx_b.window(qk)
        _, qps_cdf, _ = simulate_point_queries(
            wlo // GEOM.c_ipp, whi // GEOM.c_ipp, cap_b, "lru")
        emit(f"fig10/rmi/{mem_mb}MB", rres.tuning_seconds * 1e6,
             f"cam_branch={rres.best_branch};cam_qps={qps_cam_rmi:.0f}"
             f";cdfshop_branch={cb};cdfshop_qps={qps_cdf:.0f}"
             f";qps_gain={qps_cam_rmi / max(qps_cdf, 1):.2f}x"
             f";tuning_time_ratio={rres.tuning_seconds / max(ct, 1e-9):.2f}")

        # --- RadixSpline (third family, tunable via CostSession for the
        # first time — corridor eps is the knob, same grid machinery as PGM)
        try:
            rs = cam_tune_radixspline(
                keys, qpos, m_budget, GEOM, "lru",
                eps_grid=(16, 32, 64, 128, 256, 512, 1024), radix_bits=12,
                sample_rate=0.3)
        except ValueError:
            continue  # budget below the radix-table floor
        rs_idx = build_radixspline(keys, rs.best_eps, radix_bits=12)
        cap = max(1, (m_budget - rs_idx.size_bytes) // GEOM.page_bytes)
        wlo, whi = rs_idx.window(qk)
        _, qps_rs, _ = simulate_point_queries(
            wlo // GEOM.c_ipp, whi // GEOM.c_ipp, cap, "lru")
        emit(f"fig10b/radixspline/{mem_mb}MB", rs.tuning_seconds * 1e6,
             f"cam_eps={rs.best_eps};cam_qps={qps_rs:.0f}"
             f";index_kib={rs_idx.size_bytes / 1024:.0f}")


if __name__ == "__main__":
    run()
