"""Write-path benchmark: CAM-guided merge scheduling vs cache-oblivious.

A read-mostly -> write-burst -> read-mostly trace streams through three
:class:`~repro.write.WriteSession` arms that differ ONLY in the merge
scheduler:

* ``cam``     — :class:`CamMergeScheduler`: merges when the priced miss
                penalty of deferral over the horizon exceeds the merge
                burst's own I/O (Eq. 15 with a time axis);
* ``every_k`` — merge every K ingested batches (period-tuned baseline);
* ``on_full`` — merge only when the delta buffer is full (defer-everything
                baseline; the delta keeps stealing buffer-pool pages, so
                reads pay the shrunken cache the whole trace).

Accounting is identical across arms: each batch is charged its reads times
the model-priced I/O/query at the CURRENT (delta-shrunken) capacity, plus
the sorted-burst I/O of every merge the arm performs.  Every decision event
costs exactly ONE ``PricingEngine.price`` call in every arm (asserted).

Two gates hold (asserted, CI fails otherwise): the CAM arm's total I/O is
>= 1.2x lower than merge-on-full, and no worse than merge-every-K.
Results land in ``benchmarks/results/write_path.json``.

Run directly with ``--smoke`` for CI-sized inputs:

    python -m benchmarks.bench_write_path --smoke
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

from benchmarks.common import emit
from repro.core.cam import CamGeometry
from repro.core.session import GridCandidate, System
from repro.serving.trace import synthetic_drifting_trace
from repro.write import (CamMergeScheduler, EveryKScheduler, OnFullScheduler,
                         WriteConfig, WriteSession)

RESULTS = pathlib.Path(__file__).parent / "results"

GEOM = CamGeometry(c_ipp=64, page_bytes=4096)
MEMORY_PAGES = 160


def _segments(scale: int):
    """Read-mostly -> write-burst -> read-mostly (hot set shifts with the
    burst, so deferral's shrunken cache hurts exactly when writes pile up)."""
    return [
        {"events": 8 * scale, "mix": (0.9, 0.05, 0.0, 0.05, 0.0, 0.0),
         "hot_center": 0.3, "hot_width": 0.08, "hot_frac": 0.95},
        {"events": 10 * scale, "mix": (0.2, 0.0, 0.0, 0.65, 0.1, 0.05),
         "hot_center": 0.7, "hot_width": 0.25, "hot_frac": 0.8},
        {"events": 16 * scale, "mix": (0.92, 0.05, 0.0, 0.03, 0.0, 0.0),
         "hot_center": 0.3, "hot_width": 0.08, "hot_frac": 0.95},
    ]


def run(smoke: bool = False, seed: int = 0) -> dict:
    scale = 250 if smoke else 1000
    n = 100_000 if smoke else 400_000
    keys = np.sort(np.random.default_rng(seed + 1).uniform(0, 1e9, n))
    system = System(GEOM, memory_budget_bytes=(MEMORY_PAGES if smoke
                                               else 4 * MEMORY_PAGES)
                    * GEOM.page_bytes, policy="lru")
    config = WriteConfig(batch_size=scale,
                         delta_capacity_entries=160 * scale,
                         delta_entry_bytes=192.0, horizon_batches=12.0)
    candidate = GridCandidate(knob="live", eps=64, size_bytes=4096.0)
    segs = _segments(scale)
    events = synthetic_drifting_trace(keys, segs, seed=seed)

    arms = {}
    for sched in (CamMergeScheduler(), EveryKScheduler(k=8),
                  OnFullScheduler()):
        sess = WriteSession(keys, system, sched, candidate=candidate,
                            config=config)
        rep = sess.run(events)
        assert rep.engine_calls == rep.decision_events, \
            (rep.scheduler, rep.engine_calls, rep.decision_events)
        arms[rep.scheduler] = {**rep.summary(),
                               "io_per_op": rep.total_io / len(events)}
        emit(f"write_path/{rep.scheduler}",
             1e6 * arms[rep.scheduler]["io_per_op"],
             f"total_io={rep.total_io:.0f} merges={rep.merges}")

    ratio_full = arms["on_full"]["total_io"] / arms["cam"]["total_io"]
    ratio_k = arms["every_k"]["total_io"] / arms["cam"]["total_io"]
    record = {
        "n": n, "events": len(events), "segments": segs, "smoke": smoke,
        "memory_pages": int(system.memory_budget_bytes // GEOM.page_bytes),
        "config": {"batch_size": config.batch_size,
                   "delta_capacity_entries": config.delta_capacity_entries,
                   "delta_entry_bytes": config.delta_entry_bytes,
                   "horizon_batches": config.horizon_batches},
        "arms": arms,
        "on_full_over_cam_io": ratio_full,
        "every_k_over_cam_io": ratio_k,
        "gates": {
            "cam_1p2x_vs_on_full": ratio_full >= 1.2,
            "cam_no_worse_than_every_k": ratio_k >= 1.0,
        },
    }
    RESULTS.mkdir(exist_ok=True)
    out = RESULTS / "write_path.json"
    out.write_text(json.dumps(record, indent=2, default=float))
    emit("write_path/ratio", 0.0,
         f"on_full/cam={ratio_full:.2f}x every_k/cam={ratio_k:.2f}x -> {out}")
    assert record["gates"]["cam_1p2x_vs_on_full"], \
        f"cam only {ratio_full:.2f}x better than on_full (< 1.2x)"
    assert record["gates"]["cam_no_worse_than_every_k"], \
        f"cam worse than every_k ({ratio_k:.2f}x)"
    return record


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
