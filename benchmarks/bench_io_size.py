"""Table I: per-query I/O size distribution, PGM vs RMI at comparable index
sizes (osm — the weak-local-structure stress case)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import DEFAULT_N, DEFAULT_Q, GEOM, dataset, emit
from repro.data.workloads import WorkloadSpec, point_workload
from repro.index.pgm import build_pgm
from repro.index.rmi import build_rmi


def run(n=DEFAULT_N, n_queries=DEFAULT_Q):
    keys = dataset("osm", n)
    qk, _ = point_workload(keys, n_queries, WorkloadSpec("w4", seed=3))

    idx_pgm = build_pgm(keys, eps=64)
    # match RMI size to PGM size (comparable-index-size comparison)
    branch = max(64, int(idx_pgm.size_bytes / 24))
    idx_rmi = build_rmi(keys, branch)

    for name, idx in [("PGM", idx_pgm), ("RMI", idx_rmi)]:
        out = idx.window(qk)
        wlo, whi = out[0], out[1]
        pages = (whi // GEOM.c_ipp) - (wlo // GEOM.c_ipp) + 1
        io_bytes = pages * GEOM.page_bytes
        emit(f"tableI/{name}", 0.0,
             f"index_bytes={idx.size_bytes}"
             f";mean={io_bytes.mean():.1f};std={io_bytes.std():.1f}"
             f";p50={np.percentile(io_bytes, 50):.0f}"
             f";p95={np.percentile(io_bytes, 95):.0f}"
             f";p99={np.percentile(io_bytes, 99):.0f}")


if __name__ == "__main__":
    run()
