"""Table IV: CAM vs Replay vs LPM on point workloads — Q-error + time.

Ground truth = Replay-100 through the real buffer with windows from a BUILT
PGM (not the uniform-error model), like the paper.  Reported Q-error is on
the mean physical I/O per configuration, averaged across the eps sweep.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (DEFAULT_N, DEFAULT_Q, GEOM, LAYOUT, Timer,
                               dataset, emit, pgm_for, point_queries)
from repro.core import cam, lpm
from repro.core.qerror import q_error
from repro.core.replay import replay_windows

EPS_SWEEP = (16, 64, 256)
BUFFER_MB = 8


def run(datasets=("books", "osm"), workloads=("w1", "w2", "w4", "w6"),
        n=DEFAULT_N, n_queries=DEFAULT_Q, policy="lru"):
    header_done = False
    for ds in datasets:
        keys = dataset(ds, n)
        for wl in workloads:
            qk, qpos = point_queries(ds, wl, n, n_queries)
            results = {}
            truth = {}
            for eps in EPS_SWEEP:
                idx = pgm_for(ds, eps, n)
                m_budget = BUFFER_MB << 20
                cap = max(1, (m_budget - idx.size_bytes) // GEOM.page_bytes)
                wlo, whi = idx.window(qk)
                plo, phi = wlo // GEOM.c_ipp, whi // GEOM.c_ipp
                with Timer() as t_replay_full:
                    misses = replay_windows(plo, phi, cap, policy)
                truth[eps] = (misses.mean(), t_replay_full.seconds)

                for rate in (0.1, 1.0):
                    tag = f"CAM-{int(rate * 100)}"
                    est = cam.estimate_point_io(       # warm the jit cache
                        qpos, eps, n, GEOM, m_budget, idx.size_bytes,
                        policy=policy, sample_rate=rate)
                    with Timer() as t:
                        est = cam.estimate_point_io(
                            qpos, eps, n, GEOM, m_budget, idx.size_bytes,
                            policy=policy, sample_rate=rate)
                    results.setdefault(tag, []).append(
                        (est.io_per_query, t.seconds))
                    k = max(1, int(n_queries * rate))
                    with Timer() as t:
                        sel = slice(0, k)
                        m = replay_windows(plo[sel], phi[sel], cap, policy)
                    results.setdefault(f"Replay-{int(rate * 100)}", []).append(
                        (m.mean(), t.seconds))
                with Timer() as t:
                    est_lpm = lpm.lpm_estimate_from_windows(plo, phi)
                results.setdefault("LPM", []).append((est_lpm, t.seconds))

            for tag, rows in results.items():
                qerrs = [float(q_error(io, truth[eps][0]))
                         for (io, _), eps in zip(rows, EPS_SWEEP)]
                total_t = sum(t for _, t in rows)
                replay_t = sum(truth[e][1] for e in EPS_SWEEP)
                emit(f"tableIV/{ds}/{wl}/{tag}",
                     total_t / len(rows) * 1e6,
                     f"mean_qerr={np.mean(qerrs):.3f}"
                     f";speedup_vs_replay100={replay_t / max(total_t, 1e-9):.2f}x")


if __name__ == "__main__":
    run()
