"""Fig. 11: end-to-end join — INLJ vs POINT-ONLY vs RANGE-ONLY vs HYBRID
across the w1-w6 workload mixtures (1:20-scaled relation sizes)."""
from __future__ import annotations

from benchmarks.common import DEFAULT_N, LAYOUT, Timer, dataset, emit
from repro.data.workloads import WorkloadSpec, join_outer_keys
from repro.index.pgm import build_pgm
from repro.join.calibrate import calibrate
from repro.join.executors import hybrid_join, inlj, point_only, range_only

BUFFER_MB = 2          # paper: 16MB vs 200M rows; scaled ~1:10


def run(n=4_000_000, n_outer=30_000, eps=64):
    keys = dataset("books", n)
    idx = build_pgm(keys, eps)
    capacity = (BUFFER_MB << 20) // LAYOUT.page_bytes
    params = calibrate(idx, keys, LAYOUT, capacity)
    for wl in ("w1", "w2", "w3", "w4", "w5", "w6"):
        outer = join_outer_keys(keys, n_outer, WorkloadSpec(wl, seed=9))
        stats = {}
        for fn in (inlj, point_only, range_only):
            st = fn(idx, keys, outer, LAYOUT, capacity)
            stats[st.strategy] = st
        st = hybrid_join(idx, keys, outer, LAYOUT, capacity, params=params,
                         n_min=128, k_max=4096)
        stats[st.strategy] = st
        base = stats["inlj"].seconds
        emit(f"fig11/{wl}", 0.0,
             ";".join(f"{k}={v.seconds:.4f}s(io={v.physical_ios})"
                      for k, v in stats.items())
             + f";hybrid_speedup_vs_inlj={base / max(stats['hybrid'].seconds, 1e-12):.2f}x"
             + f";range_segs={stats['hybrid'].n_range_segments}"
               f"/{stats['hybrid'].n_segments}")


if __name__ == "__main__":
    run()
