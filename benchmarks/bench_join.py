"""Fig. 11: end-to-end join through the JoinSession plan API.

Four sections:

* fig11/*    — INLJ vs POINT-ONLY vs RANGE-ONLY vs HYBRID across the w1-w6
               outer mixtures, all executed as plans of one JoinSession;
               ``choose`` column records whether CAM-predicted selection
               matched the replayed best.
* mix/*      — Workload.mixed read-blend outer streams (sorted-run / point
               blends per the ROADMAP "workload shapes" item).
* partition/ — vectorized Algorithm 2 vs the legacy per-probe Python loop
               on the probe stream (golden-identical segments required);
               speedup recorded to benchmarks/results/join_partition.json.
* tree/      — 3-level JoinTreeSession under ONE shared buffer pool: the
               solved budget split + per-level strategies vs a naive even
               split vs the exhaustive-replay best, recorded to
               benchmarks/results/join_tree.json.

Run directly with ``--smoke`` for CI-sized inputs:

    python -m benchmarks.bench_join --smoke
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks.common import GEOM, dataset, emit
from repro.core.session import System
from repro.core.workload import Workload, locate
from repro.data.workloads import WorkloadSpec, join_outer_keys
from repro.index.adapters import PGMAdapter
from repro.join.hybrid import partition_probes, partition_probes_loop
from repro.join.session import STRATEGIES, JoinSession
from repro.join.tree import JoinTreeSession

BUFFER_MB = 2          # paper: 16MB vs 200M rows; scaled ~1:10
RESULTS = pathlib.Path(__file__).parent / "results"


def _session(keys, eps):
    inner = PGMAdapter.build(keys, eps)
    system = System(GEOM, memory_budget_bytes=(BUFFER_MB << 20)
                    + inner.size_bytes, policy="lru")
    s = JoinSession(inner, system, inner_keys=keys)
    s.calibrate()
    return s


def _mixed_outer(keys, n_outer, sorted_frac, seed=9):
    """Read-blend outer stream: a contiguous sorted run + mixture points."""
    rng = np.random.default_rng(seed)
    n_run = int(n_outer * sorted_frac)
    parts = []
    if n_outer - n_run:
        qk = join_outer_keys(keys, n_outer - n_run, WorkloadSpec("w4", seed=seed))
        parts.append(Workload.point(locate(keys, qk), n=len(keys),
                                    query_keys=qk))
    if n_run:
        start = int(rng.integers(0, max(1, len(keys) - n_run)))
        run = keys[start:start + n_run]
        parts.append(Workload.point(locate(keys, run), n=len(keys),
                                    query_keys=run))
    return Workload.mixed(*parts)


def run(n=4_000_000, n_outer=30_000, eps=64):
    keys = dataset("books", n)
    session = _session(keys, eps)

    # ---- fig11: the four strategies as plans + model-guided selection ----
    for wl in ("w1", "w2", "w3", "w4", "w5", "w6"):
        outer = join_outer_keys(keys, n_outer, WorkloadSpec(wl, seed=9))
        res = session.choose(outer, n_min=128, k_max=4096)
        stats = {s: session.execute(res.plans[s]) for s in STRATEGIES}
        best = min(stats, key=lambda s: stats[s].seconds)
        hy = stats["hybrid"]
        emit(f"fig11/{wl}", 0.0,
             ";".join(f"{k}={v.seconds:.4f}s(io={v.physical_ios})"
                      for k, v in stats.items())
             + f";choose={res.strategy}(best={best},"
               f"ratio={stats[res.strategy].seconds / max(stats[best].seconds, 1e-12):.2f})"
             + f";hybrid_speedup_vs_inlj="
               f"{stats['inlj'].seconds / max(hy.seconds, 1e-12):.2f}x"
             + f";range_segs={hy.n_range_segments}/{hy.n_segments}")

    # ---- mixed read-blend outer streams (Workload.mixed) ----
    for frac in (0.0, 0.5, 0.9):
        outer = _mixed_outer(keys, n_outer, frac)
        res = session.choose(outer, n_min=128, k_max=4096)
        stats = {s: session.execute(res.plans[s]) for s in STRATEGIES}
        best = min(stats, key=lambda s: stats[s].seconds)
        emit(f"mix/sorted{int(frac * 100):02d}", 0.0,
             f"choose={res.strategy};best={best};"
             f"ratio={stats[res.strategy].seconds / max(stats[best].seconds, 1e-12):.2f};"
             + ";".join(f"{k}={v.seconds:.4f}s" for k, v in stats.items()))

    # ---- vectorized vs loop Algorithm 2 ----
    outer = join_outer_keys(keys, n_outer, WorkloadSpec("w4", seed=9))
    plan = session.plan(outer, "hybrid", n_min=128, k_max=4096)
    plo, phi = plan.page_lo, plan.page_hi
    p = session.params
    t0 = time.perf_counter()
    segs_v = partition_probes(plo, phi, p, n_min=128, k_max=4096)
    t_vec = time.perf_counter() - t0
    t0 = time.perf_counter()
    segs_l = partition_probes_loop(plo, phi, p, n_min=128, k_max=4096)
    t_loop = time.perf_counter() - t0
    identical = segs_v == segs_l
    record = {"n_probes": int(plo.shape[0]), "segments": len(segs_v),
              "loop_seconds": t_loop, "vectorized_seconds": t_vec,
              "speedup": t_loop / max(t_vec, 1e-12), "identical": identical}
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "join_partition.json").write_text(json.dumps(record, indent=2))
    emit("partition/vectorized_vs_loop", t_vec * 1e6,
         f"speedup={record['speedup']:.1f}x;segments={len(segs_v)};"
         f"identical={identical}")

    # ---- tree/: 3-level join tree sharing one buffer pool ----
    # Sparse outer probes + LFU make strategy choice capacity-dependent,
    # so the pool split genuinely matters; see examples/join_tree.py.
    tree_keys = [keys, keys[::2].copy(), keys[::3].copy()]
    tree_adapters = [PGMAdapter.build(k, 32) for k in tree_keys]
    idx_bytes = sum(a.size_bytes for a in tree_adapters)
    pool_pages = max(256, GEOM.num_pages(n) // 5)
    tree_outer = join_outer_keys(keys, max(800, n // 250),
                                 WorkloadSpec("w2", seed=9))
    grid = 8
    system = System(GEOM, memory_budget_bytes=pool_pages * GEOM.page_bytes
                    + idx_bytes, policy="lfu")
    tree = JoinTreeSession(tree_adapters, system, tree_keys)
    t0 = time.perf_counter()
    plan = tree.plan(tree_outer, grid=grid, objective="io",
                     n_min=64, k_max=4096)
    t_plan = time.perf_counter() - t0
    stats = tree.execute(plan)

    streams = tree.probe_streams(tree_outer)
    params = tree.sessions[0].params
    # even-split baseline: same pool split 1/L, per-level strategy still
    # chosen by predicted io (same objective as the tree plan, so the
    # recorded ratio isolates what the budget-split SOLVE buys)
    even_cap = max(1, tree.pool_pages // tree.n_levels)
    even_io = 0
    for i, sess in enumerate(tree.sessions):
        curve = sess.cost_curve(streams[i], [even_cap], n_min=64,
                                k_max=4096, params=params)
        strategy, _ = curve.best_at(0, "io")
        even_io += sess.execute(sess.plan(streams[i], strategy, n_min=64,
                                          k_max=4096, params=params,
                                          capacity=even_cap)).physical_ios

    # exhaustive-replay best over (split simplex x per-level strategy):
    # levels are independent given the split, so replay each
    # (level, capacity, strategy) once and minimize over compositions.
    from itertools import combinations
    shares = np.arange(1, grid - tree.n_levels + 2)
    caps = np.maximum(1, (shares * tree.pool_pages) // grid)
    io_tab = np.empty((tree.n_levels, len(caps)))
    for lvl, sess in enumerate(tree.sessions):
        for j, cap in enumerate(caps):
            io_tab[lvl, j] = min(
                sess.execute(sess.plan(streams[lvl], st, n_min=64,
                                       k_max=4096, params=params,
                                       capacity=int(cap))).physical_ios
                for st in STRATEGIES)
    bars = np.array(list(combinations(range(1, grid), tree.n_levels - 1)))
    edges = np.concatenate(
        [np.zeros((bars.shape[0], 1), np.int64), bars,
         np.full((bars.shape[0], 1), grid)], axis=1)
    comps = np.diff(edges, axis=1)
    best_io = float(io_tab[np.arange(tree.n_levels)[None, :],
                           comps - 1].sum(axis=1).min())

    record = {"n_inner": n, "n_outer": int(tree_outer.shape[0]),
              "pool_pages": tree.pool_pages, "grid": grid, "policy": "lfu",
              "fractions": list(plan.fractions),
              "strategies": list(plan.strategies),
              "plan_seconds": t_plan,
              "chosen_io": int(stats.physical_ios),
              "even_split_io": int(even_io),
              "best_replay_io": best_io,
              "chosen_vs_best": stats.physical_ios / max(best_io, 1.0),
              "even_vs_chosen": even_io / max(stats.physical_ios, 1)}
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "join_tree.json").write_text(json.dumps(record, indent=2))
    emit("tree/split_vs_even", t_plan * 1e6,
         f"chosen_io={stats.physical_ios};even_io={even_io};"
         f"best_replay_io={best_io:.0f};"
         f"chosen_vs_best={record['chosen_vs_best']:.2f};"
         f"split={'/'.join(f'{f:.3f}' for f in plan.fractions)};"
         f"strategies={'/'.join(plan.strategies)}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized inputs (~20x below the CPU default)")
    args = ap.parse_args()
    if args.smoke:
        run(n=200_000, n_outer=5_000)
    else:
        run()
