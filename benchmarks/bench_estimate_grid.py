"""Batched knob-grid estimation: ``CostSession.estimate_grid`` vs the legacy
per-candidate ``estimate_point_io`` loop (the seed tuner's inner loop), over a
>= 25-candidate eps grid — plus grid-tuning all three index families through
the same session.  Results are recorded to ``benchmarks/results/estimate_grid.json``.

The legacy loop pays K Python round trips and K per-eps jit specializations
(``point_page_refs`` marks eps static); the grid path compiles ONE kernel for
the whole grid and solves every hit-rate fixed point in a single vmapped
bisection over shared page-ref state.
"""
from __future__ import annotations

import json
import os
import time
import warnings

import numpy as np

from benchmarks.common import DEFAULT_N, GEOM, dataset, emit
from repro.core import cam
from repro.core.session import CostSession, GridCandidate, System
from repro.core.workload import Workload
from repro.data.workloads import WorkloadSpec, point_workload
from repro.tuning.session import (PGMBuilder, RadixSplineBuilder, RMIBuilder,
                                  TuningSession)

OUT_PATH = os.path.join(os.path.dirname(__file__), "results",
                        "estimate_grid.json")


def _eps_grid(k: int = 28) -> tuple:
    return tuple(int(e) for e in
                 dict.fromkeys(np.round(np.geomspace(4, 4096, k)).astype(int)))


def run(n=DEFAULT_N, n_queries=100_000, budget_mb=4, out_path=OUT_PATH):
    keys = dataset("books", n)
    qk, qpos = point_workload(keys, n_queries, WorkloadSpec("w4", seed=3))
    budget = int(budget_mb * 2**20)
    grid = _eps_grid()
    size_model = PGMBuilder(keys).size_model()
    sizes = {e: float(size_model(eps=e)) for e in grid}
    feasible = [e for e in grid if sizes[e] < budget - GEOM.page_bytes]

    def legacy_loop():
        out = {}
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            for eps in feasible:
                out[eps] = cam.estimate_point_io(
                    qpos, eps, n, GEOM, budget, sizes[eps], policy="lru")
        return out

    session = CostSession(System(GEOM, budget, "lru"))
    wl = Workload.point(qpos, n=n)
    cands = [GridCandidate(knob=e, eps=e, size_bytes=sizes[e]) for e in grid]

    t0 = time.perf_counter()
    loop_cold = legacy_loop()
    loop_cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    legacy_loop()
    loop_warm_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    res = session.estimate_grid(cands, wl)
    grid_cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = session.estimate_grid(cands, wl)
    grid_warm_s = time.perf_counter() - t0

    # --- sorted-stream grid: policy-aware sorted-scan path ------------------
    # One shared (R, N, coverage, solo) profile + one vmapped solve; run it
    # under LFU so the frequency-aware closed form (not just the compulsory
    # Theorem III.1 form) is on the measured path.
    wlo = np.sort(qpos)
    sorted_wl = Workload.sorted_stream(
        np.maximum(wlo - 64, 0), np.minimum(wlo + 64, n - 1), n=n)
    sorted_session = CostSession(System(GEOM, budget, "lfu"))
    t0 = time.perf_counter()
    sres = sorted_session.estimate_grid(cands, sorted_wl)
    sorted_cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    sres = sorted_session.estimate_grid(cands, sorted_wl)
    sorted_warm_s = time.perf_counter() - t0

    rel_err = max(
        abs(res.estimates[e].io_per_query - loop_cold[e].io_per_query)
        / max(loop_cold[e].io_per_query, 1e-9)
        for e in feasible)

    # --- the same session API grid-tunes every family -----------------------
    small = min(n, 500_000)
    skeys = keys[:small]
    sqk, sqpos = point_workload(skeys, min(n_queries, 30_000),
                                WorkloadSpec("w4", seed=3))
    tuning = TuningSession(System(GEOM, 2 << 20, "lru"))
    swl = Workload.point(sqpos, n=small, query_keys=sqk)
    t0 = time.perf_counter()
    pgm_res = tuning.tune(PGMBuilder(skeys), swl,
                          overrides={"eps": (8, 16, 32, 64, 128, 256, 512,
                                             1024)})
    t_pgm = time.perf_counter() - t0
    t0 = time.perf_counter()
    rmi_res = tuning.tune(RMIBuilder(skeys), swl,
                          overrides={"branch": (2**8, 2**10, 2**12, 2**14)})
    t_rmi = time.perf_counter() - t0
    t0 = time.perf_counter()
    rs_res = tuning.tune(RadixSplineBuilder(skeys, ref_radix_bits=12), swl,
                         overrides={"eps": (16, 32, 64, 128, 256, 512, 1024),
                                    "radix_bits": 12})
    t_rs = time.perf_counter() - t0

    record = {
        "n": int(n),
        "n_queries": int(n_queries),
        "budget_mb": budget_mb,
        "n_candidates": len(grid),
        "n_feasible": len(feasible),
        "legacy_loop_cold_seconds": loop_cold_s,
        "legacy_loop_warm_seconds": loop_warm_s,
        "estimate_grid_cold_seconds": grid_cold_s,
        "estimate_grid_warm_seconds": grid_warm_s,
        "speedup_cold": loop_cold_s / max(grid_cold_s, 1e-9),
        "speedup_warm": loop_warm_s / max(grid_warm_s, 1e-9),
        "max_rel_io_diff_vs_legacy": rel_err,
        "best_eps": int(res.best_knob),
        "sorted_grid_cold_seconds": sorted_cold_s,
        "sorted_grid_warm_seconds": sorted_warm_s,
        "sorted_grid_policy": "lfu",
        "sorted_grid_n_estimates": len(sres.estimates),
        "sorted_grid_best_eps": int(sres.best_knob),
        "families": {
            "pgm": {"knob": "eps", "best": int(pgm_res.best_knob),
                    "est_io": pgm_res.est_io, "tuning_seconds": t_pgm},
            "rmi": {"knob": "branch", "best": int(rmi_res.best_knob),
                    "est_io": rmi_res.est_io, "tuning_seconds": t_rmi},
            "radixspline": {"knob": "eps", "best": int(rs_res.best["eps"]),
                            "est_io": rs_res.est_io, "tuning_seconds": t_rs},
        },
    }
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)

    emit("estimate_grid/loop_cold", loop_cold_s * 1e6 / len(feasible),
         f"candidates={len(feasible)}")
    emit("estimate_grid/grid_cold", grid_cold_s * 1e6 / len(feasible),
         f"speedup={record['speedup_cold']:.1f}x")
    emit("estimate_grid/grid_warm", grid_warm_s * 1e6 / len(feasible),
         f"speedup={record['speedup_warm']:.1f}x"
         f";max_rel_diff={rel_err:.2e}")
    emit("estimate_grid/sorted_grid_warm",
         sorted_warm_s * 1e6 / max(len(sres.estimates), 1),
         f"policy=lfu;candidates={len(sres.estimates)}"
         f";best_eps={int(sres.best_knob)}")
    emit("estimate_grid/families", 0.0,
         f"pgm_eps={pgm_res.best_knob};rmi_branch={rmi_res.best_knob}"
         f";rs_eps={rs_res.best['eps']};json={os.path.relpath(out_path)}")
    return record


if __name__ == "__main__":
    run()
