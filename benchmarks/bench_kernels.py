"""Kernel-level benchmark: the multi-candidate Che solver vs scalar bisection
— HBM-pass accounting (the TPU win) + CPU wall-clock sanity."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit
from repro.core.cache_models import solve_che_time
from repro.kernels import ops


def run(n_pages=200_000):
    rng = np.random.default_rng(0)
    p = rng.zipf(1.3, n_pages).astype(np.float64)
    p = jnp.asarray(p / p.sum(), jnp.float32)
    cap = n_pages * 0.1

    # warm
    t_scalar = solve_che_time(p, cap).block_until_ready()
    with Timer() as t1:
        solve_che_time(p, cap).block_until_ready()
    t_multi = ops.che_solve(p, cap, k=8, iters=16, interpret=True)
    with Timer() as t2:
        ops.che_solve(p, cap, k=8, iters=16, interpret=True).block_until_ready()

    passes_scalar = 64          # fixed-iteration bisection
    passes_multi = 16           # K=8 log-subdivision to equal precision
    consistency = float(jnp.sum(-jnp.expm1(-p * t_multi)))
    emit("kernels/che_solver", t2.seconds * 1e6,
         f"hbm_passes={passes_multi}_vs_{passes_scalar}"
         f"(traffic_reduction={passes_scalar / passes_multi:.1f}x)"
         f";scalar_s={t1.seconds:.4f};multi_interpret_s={t2.seconds:.4f}"
         f";consistency_err={abs(consistency - cap) / cap:.2e}")


if __name__ == "__main__":
    run()
