"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Default scale is CPU-sized (~100x
below paper scale, regime-preserving); see benchmarks/common.py.
``--smoke`` shrinks every benchmark that exposes a size knob another ~10x for
CI (fast, still exercising the full code paths).
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time

_SMOKE_KWARGS = {
    "n": 200_000,
    "n_queries": 20_000,
    "n_outer": 5_000,
    "n_pages": 50_000,
    "smoke": True,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of benchmark names")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized inputs (~10x below the CPU default)")
    args = ap.parse_args()

    from benchmarks import (bench_covariance, bench_engine,
                            bench_estimate_grid, bench_fetch_strategy,
                            bench_io_size, bench_join, bench_kernels,
                            bench_kv_planner, bench_pgm_tuning_curve,
                            bench_point_accuracy, bench_profile_grid,
                            bench_range_accuracy, bench_rmi_tuning_curve,
                            bench_serving_drift, bench_sharding,
                            bench_tuning_e2e, bench_write_path)

    table = {
        "point_accuracy": bench_point_accuracy.run,     # Table IV / Fig 1
        "range_accuracy": bench_range_accuracy.run,     # Table V
        "io_size": bench_io_size.run,                   # Table I
        "covariance": bench_covariance.run,             # Table II
        "fetch_strategy": bench_fetch_strategy.run,     # Fig 5 + Lemmas
        "pgm_tuning_curve": bench_pgm_tuning_curve.run,  # Fig 7
        "rmi_tuning_curve": bench_rmi_tuning_curve.run,  # Fig 8
        "tuning_e2e": bench_tuning_e2e.run,             # Figs 9/10
        "join": bench_join.run,                         # Fig 11
        "kernels": bench_kernels.run,                   # che_solver kernel
        "kv_planner": bench_kv_planner.run,             # beyond-paper (Eq.15 serving)
        "estimate_grid": bench_estimate_grid.run,       # CostSession grid vs loop
        "serving_drift": bench_serving_drift.run,       # adaptive vs static
        "write_path": bench_write_path.run,             # CAM merge scheduler
        "sharding": bench_sharding.run,                 # solved vs even split
        "engine": bench_engine.run,                     # fused executor vs host
        "profile_grid": bench_profile_grid.run,         # device occupancy kernel
    }
    names = args.only or list(table)
    print("name,us_per_call,derived")
    for name in names:
        fn = table[name]
        kwargs = {}
        if args.smoke:
            params = inspect.signature(fn).parameters
            kwargs = {k: v for k, v in _SMOKE_KWARGS.items() if k in params}
        t0 = time.perf_counter()
        try:
            fn(**kwargs)
        except Exception as e:  # noqa: BLE001 — keep the suite running
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
              file=sys.stderr, flush=True)


if __name__ == "__main__":
    main()
