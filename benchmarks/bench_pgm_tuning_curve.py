"""Fig. 7: CAM-estimated vs actual I/O across eps and eviction policies under
memory budgets — the U-shaped index-footprint/buffer trade-off.

Each (policy, budget) curve prices through ONE ``TuningSession.tune`` call
(the joint knob x split search over batched profiles); the per-knob
estimates at full capacity ARE the curve.  Replay ground truth is unchanged;
``TableSizeModel`` pins the session to the built indexes' exact footprints
so estimated and replayed capacities agree bit-for-bit."""
from __future__ import annotations

import numpy as np

from benchmarks.common import DEFAULT_N, GEOM, dataset, emit, pgm_for
from repro.core.qerror import q_error
from repro.core.replay import replay_windows
from repro.core.session import System
from repro.core.workload import Workload
from repro.data.workloads import WorkloadSpec, point_workload
from repro.tuning.session import PGMBuilder, TableSizeModel, TuningSession

EPS_GRID = (8, 16, 32, 64, 128, 256, 512, 1024)


def run(n=DEFAULT_N, n_queries=100_000, budgets_mb=(2, 4, 6)):
    keys = dataset("books", n)
    qk, qpos = point_workload(keys, n_queries, WorkloadSpec("w4", seed=3))
    wl = Workload.point(qpos, n=n)
    indexes = {eps: pgm_for("books", eps, n) for eps in EPS_GRID}
    sizes = TableSizeModel({e: float(i.size_bytes)
                            for e, i in indexes.items()})
    builder = PGMBuilder(keys)
    for policy in ("fifo", "lru", "lfu"):
        for mem_mb in budgets_mb:
            m_budget = mem_mb << 20
            session = TuningSession(System(GEOM, m_budget, policy))
            res = session.tune(builder, wl, overrides={"eps": EPS_GRID},
                               size_model=sizes)
            curve_est = {eps: est.io_per_query
                         for eps, est in res.estimates.items()}
            curve_act = {}
            for eps in curve_est:
                idx = indexes[eps]
                cap = max(1, (m_budget - idx.size_bytes) // GEOM.page_bytes)
                wlo, whi = idx.window(qk)
                misses = replay_windows(wlo // GEOM.c_ipp, whi // GEOM.c_ipp,
                                        cap, policy)
                curve_act[eps] = float(misses.mean())
            best_est = res.best_knob
            best_act = min(curve_act, key=curve_act.get)
            qerrs = [float(q_error(curve_est[e], curve_act[e])) for e in curve_est]
            emit(f"fig7/{policy}/{mem_mb}MB",
                 res.tuning_seconds * 1e6 / max(len(curve_est), 1),
                 f"eps_star_cam={best_est};eps_star_actual={best_act}"
                 f";curve_qerr={np.mean(qerrs):.3f}"
                 f";ushaped={int(_is_ushaped(curve_act))}")


def _is_ushaped(curve):
    eps_sorted = sorted(curve)
    vals = [curve[e] for e in eps_sorted]
    best = int(np.argmin(vals))
    return vals[-1] > vals[best] or vals[0] > vals[best]


if __name__ == "__main__":
    run()
