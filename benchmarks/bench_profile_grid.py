"""Device profiling benchmark: the banded one-hot matmul occupancy kernel
vs the host LUT-gather + bincount reference.

Builds a synthetic mixed-eps profiling batch (K candidate rows x Q point
queries, pow2 leaf-eps classes drawn per reference — the §V-C RMI shape)
and runs the SAME batch through both mixed-eps kernels:

* ``host``   — ``core.page_ref.point_page_refs_mixed_eps_grid`` (gathered
  float64 LUT rows + ``np.bincount`` per class);
* ``device`` — ``kernels.profile_grid.point_page_refs_mixed_eps_grid``:
  per-class occupancy as banded one-hot matmuls in ONE pallas launch,
  histogram rows born (and staying) in HBM for the chained profile→price
  path.

On a real TPU backend the device kernel must be >= 2x faster warm (that is
the point: the histograms feed the fused price kernel without a host
round-trip).  Under interpret mode (CPU CI) kernel timings are
meaningless, so the gate degrades to structure-only: <= 2e-6 normalized
occupancy equivalence and matching totals — asserted on both backends.
Results land in ``benchmarks/results/profile_grid.json``.

Run directly with ``--smoke`` for CI-sized inputs:

    python -m benchmarks.bench_profile_grid --smoke
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks.common import GEOM, emit
from repro.core import page_ref
from repro.kernels import profile_grid

RESULTS = pathlib.Path(__file__).parent / "results"

K_ROWS = 8                   # candidate rows profiled per launch
EPS_CLASSES = (4, 16, 64, 256, 1024)   # pow2 leaf-eps mixture
REPEATS = 3
GATE_SPEEDUP = 2.0


def _batch(num_pages: int, nq: int, seed: int):
    rng = np.random.default_rng(seed)
    # zipf-ish hot set over the key space, like a w4 point workload
    pos = rng.zipf(1.2, nq) % (num_pages * GEOM.c_ipp)
    eps_rows = rng.choice(EPS_CLASSES, size=(K_ROWS, nq)).astype(np.int64)
    return pos.astype(np.int64), eps_rows


def _time(fn, repeats: int = REPEATS) -> float:
    fn()                                            # warm (jit compile)
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(smoke: bool = False, seed: int = 0) -> dict:
    import jax

    num_pages, nq = (512, 20_000) if smoke else (4096, 200_000)
    positions, eps_rows = _batch(num_pages, nq, seed)

    counts_h, totals_h = page_ref.point_page_refs_mixed_eps_grid(
        positions, eps_rows, GEOM.c_ipp, num_pages)
    counts_d, totals_d = profile_grid.point_page_refs_mixed_eps_grid(
        positions, eps_rows, GEOM.c_ipp, num_pages)
    ch = np.asarray(counts_h, np.float64)
    cd = np.asarray(counts_d, np.float64)
    scale = max(1.0, float(ch.max()))
    dh = float(np.max(np.abs(ch - cd))) / scale
    dt = float(np.max(np.abs(np.asarray(totals_h) - np.asarray(totals_d))
                      / np.maximum(np.asarray(totals_h), 1.0)))
    equivalent = dh < 2e-6 and dt < 2e-6

    host_s = _time(lambda: page_ref.point_page_refs_mixed_eps_grid(
        positions, eps_rows, GEOM.c_ipp, num_pages))
    device_s = _time(lambda: np.asarray(
        profile_grid.point_page_refs_mixed_eps_grid(
            positions, eps_rows, GEOM.c_ipp, num_pages)[0]))
    speedup = host_s / device_s
    on_tpu = jax.default_backend() == "tpu"

    record = {
        "rows": K_ROWS, "queries": nq, "num_pages": num_pages,
        "c_ipp": GEOM.c_ipp, "eps_classes": list(EPS_CLASSES),
        "backend": jax.default_backend(),
        "fused_timed": on_tpu,          # interpret timings are meaningless
        "host_seconds_warm": host_s, "device_seconds_warm": device_s,
        "device_over_host_speedup": speedup,
        "max_norm_occupancy_diff": dh, "max_rel_totals_diff": dt,
        "smoke": smoke,
        "gates": {
            "float32_equivalent": bool(equivalent),
            f"fused_{GATE_SPEEDUP}x_warm": (bool(speedup >= GATE_SPEEDUP)
                                            if on_tpu else None),
        },
    }
    RESULTS.mkdir(exist_ok=True)
    out = RESULTS / "profile_grid.json"
    out.write_text(json.dumps(record, indent=2, default=float))
    emit("profile/host", 1e6 * host_s, f"{K_ROWS}x{nq} refs warm")
    emit("profile/device", 1e6 * device_s,
         f"speedup={speedup:.2f}x dh={dh:.1e} "
         f"({'timed' if on_tpu else 'interpret: structure-only'}) -> {out}")

    assert equivalent, (
        f"occupancy kernels diverge: norm dh = {dh}, totals dt = {dt}")
    if on_tpu:
        assert speedup >= GATE_SPEEDUP, (
            f"device profiling only {speedup:.2f}x over host "
            f"(< {GATE_SPEEDUP}x) on {K_ROWS}x{nq} references")
    return record


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized inputs")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(smoke=args.smoke, seed=args.seed)


if __name__ == "__main__":
    main()
