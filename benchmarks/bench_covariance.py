"""Table II: relative contribution of Cov(H, DAC) to E[IO] across policies,
eps, and memory budgets — the justification for dropping the covariance term
in Eq. 3 (paper finds |r| <= ~3.7%)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import DEFAULT_N, GEOM, dataset, emit, pgm_for
from repro.data.workloads import WorkloadSpec, point_workload
from repro.core.replay import replay_windows


def run(n=DEFAULT_N, n_queries=100_000):
    keys = dataset("books", n)
    qk, _ = point_workload(keys, n_queries, WorkloadSpec("w4", seed=3))
    for policy in ("fifo", "lru", "lfu"):
        for eps in (8, 16, 64):
            idx = pgm_for("books", eps, n)
            for mem_mb in (2, 4, 6):
                cap = max(1, ((mem_mb << 20) - idx.size_bytes) // GEOM.page_bytes)
                wlo, whi = idx.window(qk)
                plo, phi = wlo // GEOM.c_ipp, whi // GEOM.c_ipp
                dac = (phi - plo + 1).astype(np.float64)
                misses = replay_windows(plo, phi, cap, policy).astype(np.float64)
                hit_frac = 1.0 - misses / dac
                e_io = misses.mean()
                # E[IO] = (1-E[H])E[DAC] - Cov(H, DAC)  (Eq. 2)
                cov = np.mean(hit_frac * dac) - hit_frac.mean() * dac.mean()
                r = -cov / max(e_io, 1e-12) * 100.0
                emit(f"tableII/{policy}/eps{eps}/{mem_mb}MB", 0.0,
                     f"E_IO={e_io:.3f};r_pct={r:.3f}")


if __name__ == "__main__":
    run()
